"""PUL microbenchmark playground — the paper's figures, interactively.

  PYTHONPATH=src python examples/pul_microbench.py

Sweeps the three PUL knobs (distance, transfer size, issue strategy) on the
calibrated DMA twin for every memory tier, prints the paper-style summary,
and validates each swept configuration through the real Pallas kernels.
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DMAEngine, DRAM, HBM, NVM, REMOTE_HBM, MICROBLAZE,
                        TPU_V5E_VPU, IssueStrategy, PULConfig, plan_stream)
from repro.kernels import pul_gather, ref

TIERS = [("dram", DRAM, MICROBLAZE), ("nvm", NVM, MICROBLAZE),
         ("hbm", HBM, TPU_V5E_VPU), ("remote_hbm", REMOTE_HBM, TPU_V5E_VPU)]

print(f"{'tier':12s}{'d*':>4s}{'bound':>11s}{'util@d*':>9s}{'speedup':>9s}")
for name, tier, pe in TIERS:
    eng = DMAEngine(tier, pe)
    blk = 8192 if pe is TPU_V5E_VPU else 64
    fl = blk // 4
    plan = plan_stream(block_bytes=blk, flops_per_block=fl, tier=tier, pe=pe)
    kw = dict(n_blocks=256, block_bytes=blk, compute_flops_per_block=fl)
    st = eng.run_stream(plan.cfg, **kw)
    base = eng.run_stream(plan.cfg, interleave=False, **kw)
    print(f"{name:12s}{plan.cfg.distance:4d}{plan.bound:>11s}"
          f"{st.pe_utilization:9.2f}{base.total_time/st.total_time:9.2f}x")

print("\ntransfer-size sweep on NVM (paper Fig 6):")
eng = DMAEngine(NVM, MICROBLAZE)
for size in (64, 256, 1024, 4096):
    st = eng.run_stream(PULConfig(distance=16), n_blocks=512,
                        block_bytes=size, compute_flops_per_block=size // 4)
    print(f"  {size:5d}B  bw {st.io_throughput/2**20:8.1f} MiB/s  "
          f"util {st.pe_utilization:.2f}")

print("\nbatch vs sequential issue (paper Fig 5-D):")
for d in (2, 4, 8, 16):
    kw = dict(n_blocks=512, block_bytes=64, compute_flops_per_block=16)
    tb = eng.run_stream(PULConfig(distance=d), **kw).total_time
    ts = eng.run_stream(PULConfig(distance=d,
                                  strategy=IssueStrategy.SEQUENTIAL),
                        **kw).total_time
    print(f"  d={d:2d}  batch {tb*1e6:7.1f} us   sequential {ts*1e6:7.1f} us")

# functional cross-check through the real kernel at every knob
table = jax.random.normal(jax.random.PRNGKey(0), (512, 128), jnp.float32)
trace = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 512, jnp.int32)
for d in (1, 4, 16):
    for strat in IssueStrategy:
        got = pul_gather(table, trace, cfg=PULConfig(distance=d, strategy=strat))
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.gather_ref(table, trace)))
print("\nall swept configs validated through the Pallas kernel ✓")

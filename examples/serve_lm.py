"""Paged-KV serving example: continuous batching over the zoo.

  PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-27b]

Spins up the paged engine on a reduced config, submits a burst of requests
with different lengths (two sharing a prompt prefix, so their prompt pages
are physically shared), preempts one mid-stream to push its pages through
the cold tier, and checks every token stream against the dense-cache
reference engine's math by re-running the victims after restore.
"""
import sys
sys.path.insert(0, "src")

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import PagedServingEngine, Request, ServingConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=10)
    ServingConfig.add_flags(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # the shared flag surface, with this example's tighter defaults layered
    # on top (3 slots, small pages, a bucket ladder that starts at one page)
    eng = PagedServingEngine(cfg, params, dataclasses.replace(
        ServingConfig.from_flags(args),
        batch_slots=3, max_seq=96, page_tokens=8,
        prefill_buckets=(8, 16, 32)))
    print(f"[serve_lm] paged engine: {eng.layout.features} KV features/token,"
          f" planned restore distance d*={eng.pool.distance}")

    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, size=8).tolist()   # 1 full page
    reqs = []
    for i in range(args.requests):
        if i < 2:       # two requests share an 8-token (page-aligned) prefix
            prompt = shared + rng.integers(
                1, cfg.vocab_size, size=rng.integers(1, 6)).tolist()
        else:
            prompt = rng.integers(1, cfg.vocab_size,
                                  size=rng.integers(3, 12)).tolist()
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=args.max_new))
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    # let the first batch decode a little, then swap one slot out and back
    for _ in range(4):
        eng.step()
    victim = next((i for i, r in enumerate(eng.slot_req) if r is not None),
                  None)
    if victim is not None:
        eng.preempt(victim)
        eng.step()
        eng.resume(victim)
    out = eng.run()
    dt = time.time() - t0
    for rid in sorted(out):
        print(f"[serve_lm] req {rid}: +{len(out[rid])} tokens -> {out[rid]}")
    total = sum(len(v) for v in out.values())
    snap = eng.snapshot()
    print(f"[serve_lm] {total} tokens, {total/dt:.1f} tok/s "
          f"({args.requests} reqs over 3 slots)")
    print(f"[serve_lm] pages: {snap['pages_allocated']} allocated, "
          f"{snap['shared_page_hits']} prefix-shared, "
          f"{snap['evictions']} evicted, {snap['page_faults']} restored")
    assert all(len(v) == args.max_new for v in out.values())
    assert snap["shared_page_hits"] >= 1, "prefix pages should be shared"
    if victim is not None:
        assert snap["evictions"] >= 1 and snap["page_faults"] >= 1


if __name__ == "__main__":
    main()

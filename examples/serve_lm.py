"""Batched serving example: continuous-batching engine over the zoo.

  PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-27b]

Spins up the slot-scheduler engine on a reduced config, submits a burst of
requests with different lengths, and verifies the engine's outputs equal
naive one-at-a-time decoding.
"""
import sys
sys.path.insert(0, "src")

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import EngineConfig, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=3, max_seq=96,
                                     prefill_bucket=16))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab_size,
                                               size=rng.integers(3, 12)).tolist(),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    out = eng.run()
    dt = time.time() - t0
    for rid in sorted(out):
        print(f"[serve_lm] req {rid}: +{len(out[rid])} tokens -> {out[rid]}")
    total = sum(len(v) for v in out.values())
    print(f"[serve_lm] {total} tokens, {total/dt:.1f} tok/s "
          f"({args.requests} reqs over 3 slots)")
    assert all(len(v) == args.max_new for v in out.values())


if __name__ == "__main__":
    main()

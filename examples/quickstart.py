"""Quickstart: the PUL engine in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py

1. Plan a preload schedule analytically (distance, expected utilization).
2. Run the schedule for real through the Pallas kernel (interpret on CPU,
   Mosaic DMA on TPU) and check it against the jnp oracle.
3. Sweep the distance knob on the calibrated DMA twin — the paper's Fig 5.
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DMAEngine, HBM, NVM, MICROBLAZE, TPU_V5E_VPU,
                        PULConfig, plan_stream, speedup)
from repro.kernels import pul_sum, ref

# -- 1. plan -----------------------------------------------------------
plan = plan_stream(block_bytes=64 * 128 * 4, flops_per_block=64 * 128,
                   tier=HBM, pe=TPU_V5E_VPU)
print(f"planned preload distance d*={plan.cfg.distance} "
      f"(bound: {plan.bound}, predicted PE utilization "
      f"{plan.predicted_utilization:.0%})")

# -- 2. run the real kernel against the oracle --------------------------
data = jax.random.normal(jax.random.PRNGKey(0), (4096, 128), jnp.float32)
trace = jax.random.randint(jax.random.PRNGKey(1), (256,), 0, 4096, jnp.int32)
cfg = PULConfig(distance=plan.cfg.distance)
got = pul_sum(data, trace, cfg=cfg)
want = ref.sum_ref(data, trace)
np.testing.assert_allclose(got, want, rtol=1e-5)
print(f"pul_sum(trace of 256 random rows) = {float(got):.3f}  == oracle ✓")

# -- 3. the paper's distance sweep (Fig 5-A) ----------------------------
eng = DMAEngine(NVM, MICROBLAZE)
print("\ndistance sweep on the calibrated NVM twin (paper Fig 5-A):")
for d in (1, 2, 4, 8, 16, 32):
    st = eng.run_stream(PULConfig(distance=d), n_blocks=512, block_bytes=64,
                        compute_flops_per_block=16)
    bar = "#" * int(st.pe_utilization * 40)
    print(f"  d={d:2d}  {st.total_time*1e6:7.1f} us  util {bar}")
s = speedup(eng, PULConfig(distance=16), n_blocks=512, block_bytes=64,
            compute_flops_per_block=16)
print(f"\ninterleaved vs phase-separated: {s:.2f}x  (paper: 2.9x on NVM)")

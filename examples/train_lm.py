"""End-to-end training driver: ~100M-class model for a few hundred steps.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch qwen3-1.7b]

Builds a ~100M-parameter variant of the chosen architecture (full-depth
structure, narrower width), trains it on the synthetic packed LM stream with
the production train_step (remat + accum + AdamW + async checkpointing), and
prints the loss curve. On CPU this takes a few minutes; the identical code
path drives the full configs on TPU slices.
"""
import sys
sys.path.insert(0, "src")

import argparse
import dataclasses
import time

import jax

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import OptimizerConfig, adamw_init


def small_100m(arch: str):
    """Full layer structure, ~100M params."""
    cfg = get_config(arch)
    pat = len(cfg.pattern)
    layers = cfg.first_dense_layers + max(1, 8 // pat) * pat
    over = dict(num_layers=layers, d_model=512, num_heads=8,
                num_kv_heads=min(cfg.num_kv_heads, 4) or 4, head_dim=64,
                d_ff=2048, vocab_size=32768, vocab_chunk=8192, train_accum=1)
    if cfg.num_kv_heads == cfg.num_heads:
        over["num_kv_heads"] = 8
    if cfg.num_experts:
        over.update(num_experts=8, experts_per_tok=2, moe_d_ff=1024)
    if cfg.ssm_heads:
        over.update(ssm_heads=8, ssm_head_dim=64, d_inner=1024,
                    ssm_state=32 if cfg.ssm_state else 0)
    if cfg.sliding_window:
        over["sliding_window"] = 256
    if cfg.shared_lora_rank:
        over["shared_lora_rank"] = 32
    if cfg.frontend_tokens:
        over["frontend_tokens"] = 16
    return dataclasses.replace(cfg, **over)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/pulse_train_lm")
    args = ap.parse_args()

    cfg = small_100m(args.arch)
    model = build_model(cfg)
    print(f"[train_lm] {args.arch} variant: {model.num_params()/1e6:.1f}M params, "
          f"{cfg.num_layers} layers")

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, OptimizerConfig(
        lr=6e-4, warmup_steps=20, total_steps=args.steps)),
        donate_argnums=(0, 1))
    data = TokenPipeline(DataConfig(
        global_batch=args.batch, seq_len=args.seq, vocab_size=cfg.vocab_size,
        frontend_tokens=cfg.frontend_tokens, d_model=cfg.d_model,
        prefetch_distance=2))
    mgr = CheckpointManager(CheckpointConfig(args.ckpt_dir, keep=2))
    data.start()

    t0 = time.time()
    first = None
    for step in range(args.steps):
        params, opt, m = step_fn(params, opt, next(data))
        if step == 0:
            first = float(m["loss"])
        if (step + 1) % 25 == 0:
            print(f"[train_lm] step {step+1:4d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, (params, opt))      # async unload
    mgr.wait()
    data.stop()
    last = float(m["loss"])
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} "
          f"({args.steps} steps, {time.time()-t0:.0f}s)")
    assert last < first - 1.0, "training did not learn"


if __name__ == "__main__":
    main()

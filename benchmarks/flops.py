"""Analytic execution-cost model per (arch x shape) cell.

Why analytic: XLA's `cost_analysis()` counts `lax.scan` bodies ONCE
(empirically verified — see EXPERIMENTS.md §Dry-run), so for scanned-layer /
microbatched / chunked programs the compiled counter underestimates by the
trip counts. This model counts every einsum actually executed by the code in
src/repro/models, per cell:

  MODEL_FLOPS  = 6 * N_active * tokens  (train)  |  2 * N_active * tokens
                 (prefill/decode)  — the "useful" MFU numerator.
  EXEC_FLOPS   = what the hardware runs: + causal-block overcompute in the
                 streaming attention, + MoE dispatch einsums (backend-aware),
                 + remat recompute (x4/3 of fwd), + chunked-loss logits.
  EXEC_BYTES   = HBM traffic: parameter shard reads per microbatch, gathered
                 weight write+read, optimizer state r/w (train); KV-cache
                 read per step (decode); activation stack save+load.

All numbers are GLOBAL (whole job); divide by chips for per-device terms.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import InputShape, ModelConfig

BF16 = 2
F32 = 4


def _attn_flops_per_layer(cfg: ModelConfig, B: int, T: int, S_ctx: int,
                          *, window=None) -> Dict[str, float]:
    """Forward FLOPs of one attention layer over a (B, T) query block
    attending to S_ctx keys. Streaming attention computes full blocks under
    the causal mask -> score/out term uses S_ctx (not S_ctx/2)."""
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    S_eff = min(window, S_ctx) if window else S_ctx
    proj = 2 * B * T * D * (H + 2 * K) * hd + 2 * B * T * H * hd * D
    scores = 2 * B * H * T * S_eff * hd * 2          # qk^T and p@v
    return {"proj": proj, "scores": scores}


def _mla_flops_per_layer(cfg: ModelConfig, B: int, T: int, S_ctx: int,
                         decode: bool) -> Dict[str, float]:
    D, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    proj = 2 * B * T * (D * qr + qr * H * (dn + dr)        # q path
                        + D * (kvr + dr))                  # kv compress
    if decode:
        # absorbed: q_abs (H,dn,kvr), scores over (kvr + dr), out over kvr,
        # then v up-proj per head
        proj += 2 * B * T * H * (dn * kvr + dv * kvr)
        scores = 2 * B * H * T * S_ctx * (kvr + dr) * 2
    else:
        proj += 2 * B * T * kvr * H * (dn + dv)            # kv up-proj
        scores = 2 * B * H * T * S_ctx * ((dn + dr) + dv)
    proj += 2 * B * T * H * dv * D                         # out proj
    return {"proj": proj, "scores": scores}


def _mlp_flops(cfg: ModelConfig, B: int, T: int, d_ff: int) -> float:
    mult = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
    return 2 * B * T * cfg.d_model * d_ff * mult


def _moe_flops(cfg: ModelConfig, B: int, T: int) -> Dict[str, float]:
    D, E, k = cfg.d_model, cfg.num_experts, cfg.experts_per_tok
    F = cfg.moe_d_ff
    router = 2 * B * T * D * E
    experts = 2 * B * T * k * D * F * 3
    shared = 2 * B * T * D * (cfg.num_shared_experts * F) * 3 \
        if cfg.num_shared_experts else 0.0
    dispatch = 0.0
    if cfg.moe_backend == "einsum":
        Tg = 2048
        import math
        C = max(8, -(-math.ceil(Tg * k / E * cfg.capacity_factor) // 8) * 8)
        # dispatch + combine einsums (td,tec->ecd / ecd,tec->td) per group
        dispatch = 2 * (2 * Tg * E * C * D) * (B * T / Tg)
    return {"router": router, "experts": experts + shared,
            "dispatch": dispatch}


def _rwkv_flops_per_layer(cfg: ModelConfig, B: int, T: int, decode: bool) -> float:
    D, H, N = cfg.d_model, cfg.ssm_heads, cfg.ssm_head_dim
    C = 1 if decode else cfg.chunk_size
    proj = 2 * B * T * D * (4 * H * N) + 2 * B * T * (D * 64 + 64 * H * N)
    wkv = B * T * H * (3 * C * N + 2 * C * N + 4 * N * N)   # intra + inter/state
    cmix = 2 * B * T * (D * cfg.d_ff + cfg.d_ff * D + D * D)
    out = 2 * B * T * H * N * D
    return proj + wkv + cmix + out


def _mamba_flops_per_layer(cfg: ModelConfig, B: int, T: int, decode: bool) -> float:
    D, din, H, N = cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    P = din // H
    C = 1 if decode else cfg.chunk_size
    proj = 2 * B * T * D * (2 * din + 2 * N + H) + 2 * B * T * din * D
    conv = 2 * B * T * (din + 2 * N) * cfg.conv_kernel
    ssd = B * T * H * (3 * C * N + 2 * C * P + 4 * P * N)
    return proj + conv + ssd


def _layer_fwd_flops(cfg: ModelConfig, kind: str, B: int, T: int, S_ctx: int,
                     decode: bool) -> float:
    if kind == "rwkv":
        return _rwkv_flops_per_layer(cfg, B, T, decode)
    if kind == "mamba":
        return _mamba_flops_per_layer(cfg, B, T, decode)
    total = 0.0
    if cfg.attn_type == "mla":
        total += sum(_mla_flops_per_layer(cfg, B, T, S_ctx, decode).values())
    else:
        window = cfg.sliding_window if kind == "local" else None
        total += sum(_attn_flops_per_layer(cfg, B, T, S_ctx,
                                           window=window).values())
    if kind == "moe":
        total += sum(_moe_flops(cfg, B, T).values())
    elif kind == "shared_attn":
        total += _mlp_flops(cfg, B, T, cfg.d_ff)
        # LoRA merge: (D,r)@(r,HK*hd) x3, amortized per invocation
        r = cfg.shared_lora_rank
        if r:
            D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            total += 2 * D * r * (H + 2 * K) * hd
    else:
        total += _mlp_flops(cfg, B, T, cfg.d_ff)
    return total


def active_params(cfg: ModelConfig) -> float:
    """N_active: parameters that multiply activations per token (experts
    counted at top-k), embedding gather excluded, lm_head included."""
    D = cfg.d_model
    n = 0.0
    for kind in cfg.pattern:
        if kind == "rwkv":
            H, N = cfg.ssm_heads, cfg.ssm_head_dim
            n += D * 4 * H * N + D * 64 + 64 * H * N + H * N * D
            n += D * cfg.d_ff * 2 + D * D
        elif kind == "mamba":
            n += D * (2 * cfg.d_inner + 2 * cfg.ssm_state + cfg.ssm_heads)
            n += cfg.d_inner * D
        else:
            if cfg.attn_type == "mla":
                qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
                dn, dr, dv = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                              cfg.v_head_dim)
                H = cfg.num_heads
                n += D * qr + qr * H * (dn + dr) + D * (kvr + dr) \
                    + kvr * H * (dn + dv) + H * dv * D
            else:
                H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
                n += D * (H + 2 * K) * hd + H * hd * D
            mult = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
            if kind == "moe":
                n += D * cfg.num_experts * 0  # routed: only top-k active
                n += cfg.experts_per_tok * D * cfg.moe_d_ff * mult
                n += cfg.num_shared_experts * D * cfg.moe_d_ff * mult
                n += D * cfg.num_experts    # router
            else:
                n += D * cfg.d_ff * mult
    n *= cfg.num_groups
    # unscanned dense prefix
    if cfg.first_dense_layers:
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        if cfg.attn_type == "mla":
            qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
            dn, dr, dv = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                          cfg.v_head_dim)
            attn = D * qr + qr * cfg.num_heads * (dn + dr) + D * (kvr + dr) \
                + kvr * cfg.num_heads * (dn + dv) + cfg.num_heads * dv * D
        else:
            attn = D * (H + 2 * K) * hd + H * hd * D
        mult = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        n += cfg.first_dense_layers * (attn + D * cfg.d_ff * mult)
    n += cfg.vocab_size * D       # lm_head
    return n


def total_params(cfg: ModelConfig) -> float:
    from repro.models import build_model
    return float(build_model(cfg).num_params())


@dataclasses.dataclass(frozen=True)
class CellCost:
    model_flops: float            # 6ND / 2ND (global)
    exec_flops: float             # what actually runs (global)
    exec_bytes: float             # HBM traffic (global)
    tokens: float
    notes: str = ""


def cell_cost(cfg: ModelConfig, shape: InputShape, accum: int = 0) -> CellCost:
    B_, S_ = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    train = shape.kind == "train"
    accum = accum or cfg.train_accum
    Tf = cfg.frontend_tokens if cfg.frontend else 0
    T = 1 if decode else S_                 # query tokens per sequence
    S_ctx = S_                              # context length
    tokens = B_ * T

    # ---- forward flops over all layers
    fwd = 0.0
    for kind in cfg.pattern:
        fwd += _layer_fwd_flops(cfg, kind, B_, T, S_ctx, decode) * cfg.num_groups / 1.0
    if cfg.first_dense_layers:
        fwd += _layer_fwd_flops(cfg, "dense", B_, T, S_ctx, decode) \
            * cfg.first_dense_layers
    # logits: chunked loss (train) or full head (prefill last tok / decode)
    Vp = -(-cfg.vocab_size // cfg.vocab_chunk) * cfg.vocab_chunk
    if train:
        fwd += 2 * B_ * T * cfg.d_model * Vp
    else:
        fwd += 2 * B_ * 1 * cfg.d_model * cfg.vocab_size

    # ---- execution multiplier
    if train:
        exec_flops = fwd * 4.0              # fwd + remat-refwd + 2x bwd
    else:
        exec_flops = fwd

    N_act = active_params(cfg)
    model_flops = (6.0 if train else 2.0) * N_act * tokens

    # ---- bytes (HBM, global)
    N_tot = total_params(cfg)
    if train:
        # per microbatch: param shard read + gathered write + gathered read;
        # optimizer: m,v read+write fp32 + param write
        p_traffic = accum * 3 * N_tot * BF16 + N_tot * (4 * F32 + BF16)
        # activation residual stack: save + load (bf16 + the f32 artifact)
        layer_saves = cfg.num_groups * B_ * S_ * cfg.d_model * (BF16 + F32)
        a_traffic = 2 * layer_saves
        exec_bytes = p_traffic + a_traffic
    elif decode:
        cache = _cache_bytes(cfg, B_, S_)
        exec_bytes = N_tot * BF16 + cache   # read weights + read cache
    else:  # prefill
        cache = _cache_bytes(cfg, B_, S_)
        act = cfg.num_layers * B_ * S_ * cfg.d_model * BF16 * 4
        exec_bytes = N_tot * BF16 + cache + act
    return CellCost(model_flops=model_flops, exec_flops=exec_flops,
                    exec_bytes=float(exec_bytes), tokens=tokens)


def collective_bytes(cfg: ModelConfig, shape: InputShape, accum: int = 0,
                     *, fsdp: int = 16, tp: int = 16,
                     inference_replicated: bool = False) -> float:
    """Analytic per-DEVICE collective wire bytes per step.

    Needed because the HLO-parsed number counts collectives inside lax.scan
    bodies ONCE (same XLA limitation as flops); this model multiplies by the
    real trip counts. Dominant flows:
      train:   FSDP all-gather of weights (fwd + remat-bwd) and
               reduce-scatter of grads, PER MICROBATCH; TP all-reduce of
               activations per layer (fwd+bwd).
      serve:   one weight all-gather per step (unless weights are
               replicated across the data axis) + TP reductions.
    """
    accum = accum or cfg.train_accum
    B_, S_ = shape.global_batch, shape.seq_len
    T = 1 if shape.kind == "decode" else S_
    P = total_params(cfg) * BF16
    ag = P * (fsdp - 1) / fsdp          # one full weight gather, per device
    # TP activation all-reduce: ~2 tensors of (B,T,D) per layer boundary
    act = 2 * B_ * T * cfg.d_model * BF16 * cfg.num_layers * (tp - 1) / tp / tp
    if shape.kind == "train":
        per_micro = 2 * ag + ag         # AG fwd + AG remat-bwd + RS grads
        return accum * (per_micro + 3 * act) / 1.0
    weights = 0.0 if inference_replicated else ag
    return weights + act


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    total = 0.0
    for kind in cfg.pattern:
        if kind == "rwkv":
            H, N = cfg.ssm_heads, cfg.ssm_head_dim
            total += B * (H * N * N * F32 + 2 * cfg.d_model * BF16)
        elif kind == "mamba":
            H, N = cfg.ssm_heads, cfg.ssm_state
            P = cfg.d_inner // H
            total += B * (H * P * N * F32
                          + (cfg.conv_kernel - 1) * (cfg.d_inner + 2 * N) * BF16)
        else:
            if cfg.attn_type == "mla":
                total += B * S * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * BF16
            else:
                win = cfg.sliding_window if kind == "local" else None
                S_eff = min(win, S) if win else S
                total += B * S_eff * 2 * cfg.num_kv_heads * cfg.head_dim * BF16
    total *= cfg.num_groups
    if cfg.first_dense_layers:
        if cfg.attn_type == "mla":
            total += cfg.first_dense_layers * B * S * \
                (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * BF16
        else:
            total += cfg.first_dense_layers * B * S * 2 * cfg.num_kv_heads \
                * cfg.head_dim * BF16
    return total

"""Reproductions of the paper's five experiments — one function per figure.

Each experiment runs on BOTH engines this repo provides:
  * the calibrated DMA twin (`core.dma`) with the paper's own constants
    (150 MHz MicroBlaze PE, NVMulator latencies 350/170 ns, 8 GiB/s system
    bandwidth) — produces the *quantitative* figures;
  * the Pallas kernels in interpret mode — validates that the *functional*
    PUL schedule (Listing 1) computes correct results at every knob setting
    the figures sweep (distance, transfer size, strategy, unload mode).

Output: CSV rows `name,value,derived` consumed by benchmarks/run.py, plus a
CLAIM line per paper claim with pass/fail.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DMAEngine,
    DRAM,
    HBM,
    IssueStrategy,
    MICROBLAZE,
    NVM,
    PULConfig,
    REMOTE_HBM,
    UPMEM_DPU,
    plan_stream,
    speedup,
)
from repro.core.pul import MemoryTier

ROWS: List[str] = []
CLAIMS: List[str] = []


def row(name: str, value, derived: str = ""):
    ROWS.append(f"{name},{value},{derived}")


def claim(name: str, ok: bool, detail: str):
    CLAIMS.append(f"CLAIM {name}: {'PASS' if ok else 'FAIL'} ({detail})")


# ------------------------------------------------------------------ Exp 1
def exp1_roofline(n_blocks=512, block_bytes=64):
    """Fig 1/3: interleaving speedup across operational intensities, DRAM vs
    NVM, 1 vs 14 PEs. Claim: PUL lifts compute utilization >= 2x at low
    intensity; NVM gains more than DRAM."""
    intensities = [1, 4, 16, 64, 256]      # flops per block (64B transfers)
    results: Dict[str, Dict[int, float]] = {}
    for tier in (DRAM, NVM):
        eng = DMAEngine(tier, MICROBLAZE)
        for n_pes in (1, 14):
            key = f"{tier.name}_pe{n_pes}"
            results[key] = {}
            for fl in intensities:
                kw = dict(n_blocks=n_blocks, block_bytes=block_bytes,
                          compute_flops_per_block=fl)
                base = eng.scale_to_pes(
                    eng.run_stream(PULConfig(distance=16), interleave=False, **kw),
                    n_pes)
                pul = eng.scale_to_pes(
                    eng.run_stream(PULConfig(distance=16), **kw), n_pes)
                s = base.total_time / pul.total_time
                results[key][fl] = s
                row(f"exp1/speedup/{key}/intensity{fl}", f"{s:.3f}",
                    f"util {pul.pe_utilization:.2f}")
    low = results["nvm_pe1"][1]
    claim("E1.interleave>=2x@low-intensity", low >= 2.0, f"NVM 1PE: {low:.2f}x")
    claim("E1.nvm>dram", results["nvm_pe1"][1] > results["dram_pe1"][1],
          f"{results['nvm_pe1'][1]:.2f} vs {results['dram_pe1'][1]:.2f}")
    # PIM-style engine (UPMEM DPU: higher clock, DRAM-like tier)
    eng_pim = DMAEngine(DRAM, UPMEM_DPU)
    kw = dict(n_blocks=n_blocks, block_bytes=block_bytes,
              compute_flops_per_block=64)
    s_pim = speedup(eng_pim, PULConfig(distance=11), **kw)
    row("exp1/speedup/pim_11tasklets", f"{s_pim:.3f}", "11-deep window")
    claim("E1.pim-speedup>1", s_pim > 1.0, f"{s_pim:.2f}x")
    return results


# ------------------------------------------------------------------ Exp 2
def exp2_intensity_constant_time():
    """Fig 4: aggregating more attributes of a ROW-WISE record (one transfer
    per record, fixed size) leaves execution time ~flat while IPC rises,
    until compute overtakes the I/O time.

    Platform per the paper: "we investigate aggregations in PIM" — UPMEM
    DPU, whose per-PE DRAM bandwidth (~700 MB/s) gates record arrival."""
    upmem_dram = MemoryTier("upmem_dram", read_latency=620e-9,
                            write_latency=620e-9, bandwidth=700e6)
    eng = DMAEngine(upmem_dram, UPMEM_DPU)
    times, ipcs = {}, {}
    attrs = [1, 2, 4, 8, 16]
    record_bytes = 256              # 32 x 8B attributes, single transfer
    for n_attr in attrs:
        st = eng.run_stream(PULConfig(distance=16), n_blocks=256,
                            block_bytes=record_bytes,
                            compute_flops_per_block=8 * n_attr)
        times[n_attr] = st.total_time
        ipcs[n_attr] = st.ipc
        row(f"exp2/time_us/attrs{n_attr}", f"{st.total_time*1e6:.2f}",
            f"ipc {st.ipc:.3f}")
    flat = times[4] / times[1]
    claim("E2.time-flat-while-ipc-rises",
          flat < 1.25 and ipcs[4] > ipcs[1] * 1.5,
          f"t4/t1={flat:.2f}, ipc {ipcs[1]:.2f}->{ipcs[4]:.2f}")
    # DB-op positioning (Fig 4-C): NDP wait time vs op compute cost on NVM
    ops = {"sum_1attr": 8, "agg_4attr": 32, "mvcc_check": 96, "agg_16attr": 128}
    io_time = NVM.read_latency + 64 / NVM.bandwidth
    for op, fl in ops.items():
        ratio = io_time / MICROBLAZE.compute_time(fl)
        row(f"exp2/interleave_headroom/{op}", f"{ratio:.2f}",
            "ops fit per request")
    return times, ipcs


# ------------------------------------------------------------------ Exp 3
def exp3_distance():
    """Fig 5: distance sweep -> plateau ~d16 (paper's constants); batch-wise
    >= sequential below plateau; throughput/utilization rise with d."""
    eng = DMAEngine(NVM, MICROBLAZE)
    kw = dict(n_blocks=512, block_bytes=64, compute_flops_per_block=16)
    times = {}
    for d in (1, 2, 4, 8, 16, 32, 64):
        st = eng.run_stream(PULConfig(distance=d), **kw)
        times[d] = st.total_time
        row(f"exp3/time_us/d{d}", f"{st.total_time*1e6:.2f}",
            f"util {st.pe_utilization:.2f} io {st.io_throughput/2**20:.1f}MiB/s")
    plateau_ok = times[16] <= times[64] * 1.05 and times[1] > times[16] * 1.3
    claim("E3.plateau<=d16", plateau_ok,
          f"d1={times[1]*1e6:.1f}us d16={times[16]*1e6:.1f}us "
          f"d64={times[64]*1e6:.1f}us")
    for d in (2, 4, 8, 16):
        tb = eng.run_stream(PULConfig(distance=d, strategy=IssueStrategy.BATCH),
                            **kw).total_time
        ts = eng.run_stream(PULConfig(distance=d,
                                      strategy=IssueStrategy.SEQUENTIAL),
                            **kw).total_time
        row(f"exp3/batch_vs_seq/d{d}", f"{ts/tb:.4f}", "seq/batch time ratio")
    tb16 = eng.run_stream(PULConfig(distance=16), **kw).total_time
    ts16 = eng.run_stream(PULConfig(distance=16,
                                    strategy=IssueStrategy.SEQUENTIAL),
                          **kw).total_time
    claim("E3.batch>=seq,converging-at-plateau",
          abs(ts16 - tb16) / tb16 < 0.05, f"at d16: {ts16/tb16:.3f}")
    # planner cross-check (beyond paper: analytic d*)
    plan = plan_stream(block_bytes=64, flops_per_block=16, tier=NVM,
                       pe=MICROBLAZE)
    row("exp3/planner_dstar", plan.cfg.distance, plan.bound)
    return times


# ------------------------------------------------------------------ Exp 4
def exp4_transfer_size():
    """Fig 6: configurable transfer sizes raise bandwidth; PUL saturates the
    link with 2-3 PEs vs >= 8 without; too-large transfers hurt when
    bandwidth-bound."""
    eng = DMAEngine(NVM, MICROBLAZE)
    for size in (64, 256, 512, 1024, 4096, 8192):
        st = eng.run_stream(PULConfig(distance=16), n_blocks=256,
                            block_bytes=size, compute_flops_per_block=16)
        row(f"exp4/bw_MiBs/size{size}", f"{st.io_throughput/2**20:.1f}",
            f"time {st.total_time*1e6:.1f}us")
    # PEs needed to reach 90% of link bandwidth, with vs without PUL
    def pes_to_saturate(interleave: bool) -> int:
        for n in range(1, 17):
            st = eng.run_stream(PULConfig(distance=16), n_blocks=256,
                                block_bytes=4096, compute_flops_per_block=16,
                                interleave=interleave)
            agg = eng.scale_to_pes(st, n)
            if agg.io_throughput * n >= 0.9 * NVM.bandwidth / max(1, 1):
                if agg.io_throughput >= 0.9 * NVM.bandwidth / n * min(
                        n, NVM.bandwidth / max(st.io_throughput, 1)):
                    pass
            if st.io_throughput * n >= 0.9 * NVM.bandwidth:
                return n
        return 16

    n_pul = pes_to_saturate(True)
    n_nopul = pes_to_saturate(False)
    row("exp4/pes_to_saturate/pul", n_pul, "")
    row("exp4/pes_to_saturate/no_pul", n_nopul, "")
    claim("E4.pul-saturates-with-fewer-pes", n_pul < n_nopul,
          f"{n_pul} vs {n_nopul}")
    # PIM regression at large transfers (Fig 6-G): latency not amortized
    eng_pim = DMAEngine(DRAM, UPMEM_DPU)
    t32 = eng_pim.run_stream(PULConfig(distance=8), n_blocks=256,
                             block_bytes=32, compute_flops_per_block=8)
    t2k = eng_pim.run_stream(PULConfig(distance=8), n_blocks=256,
                             block_bytes=2048, compute_flops_per_block=8)
    row("exp4/pim_ipc/size32", f"{t32.ipc:.3f}", "")
    row("exp4/pim_ipc/size2048", f"{t2k.ipc:.3f}", "")
    claim("E4.pim-large-transfers-hurt-ipc", t2k.ipc < t32.ipc,
          f"{t2k.ipc:.3f} < {t32.ipc:.3f}")


# ------------------------------------------------------------------ Exp 5
def exp5_unload():
    """Fig 7: unloading interleaves flushes; bit-vector materialization
    removes the bandwidth-bound overhead of full-row result sets.

    Platform per the paper: the filter offload runs on PIM (UPMEM DPU),
    where per-PE DRAM bandwidth (~700 MB/s) makes the scan bandwidth-bound
    — the regime in which result-set width matters."""
    upmem_dram = MemoryTier("upmem_dram", read_latency=620e-9,
                            write_latency=620e-9, bandwidth=700e6)
    eng = DMAEngine(upmem_dram, UPMEM_DPU)
    kw = dict(n_blocks=256, block_bytes=64, compute_flops_per_block=8)
    t_none = eng.run_stream(PULConfig(distance=16), **kw).total_time
    # full materialization: unload whole 64B rows
    t_full = eng.run_stream(PULConfig(distance=16, unload_distance=1),
                            unload_bytes_per_block=64, **kw).total_time
    t_full_sync = eng.run_stream(PULConfig(distance=16, unload_distance=0),
                                 unload_bytes_per_block=64, **kw).total_time
    # bit-vector: 1 bit per row -> 8B per 64-row block + extra pack compute
    kw_bv = dict(n_blocks=256, block_bytes=64, compute_flops_per_block=8 + 8)
    t_bv = eng.run_stream(PULConfig(distance=16, unload_distance=1),
                          unload_bytes_per_block=8, **kw_bv).total_time
    for name, t in [("no_materialize", t_none), ("full_async", t_full),
                    ("full_sync", t_full_sync), ("bitvector", t_bv)]:
        row(f"exp5/time_us/{name}", f"{t*1e6:.2f}", "")
    claim("E5.async-unload-beats-sync-flush", t_full < t_full_sync,
          f"{t_full*1e6:.1f} < {t_full_sync*1e6:.1f} us")
    claim("E5.bitvector-removes-materialization-overhead",
          t_bv <= t_none * 1.15 and t_bv < t_full,
          f"bv {t_bv*1e6:.1f} vs none {t_none*1e6:.1f} vs full {t_full*1e6:.1f}")
    # flush-threshold sweep (Fig 7-B, NDP/NVM): larger flushes amortize
    # per-request overhead until bandwidth saturates
    eng_ndp = DMAEngine(NVM, MICROBLAZE)
    for fsize in (64, 256, 1024, 2048):
        blocks = 256 * 64 // fsize
        st = eng_ndp.run_stream(PULConfig(distance=16, unload_distance=1),
                                n_blocks=blocks, block_bytes=fsize,
                                compute_flops_per_block=16 * fsize // 64,
                                unload_bytes_per_block=fsize)
        row(f"exp5/flush_time_us/size{fsize}", f"{st.total_time*1e6:.2f}", "")


# ------------------------------------- functional validation on the kernels
def kernels_functional_sweep():
    """Every figure's knob sweep executes correctly through the Pallas
    kernels (interpret mode) — the schedule is real, not just modeled."""
    from repro.kernels import pul_filter, pul_sum, ref
    data = jax.random.normal(jax.random.PRNGKey(0), (128, 32), jnp.float32)
    trace = jax.random.randint(jax.random.PRNGKey(1), (32,), 0, 64, jnp.int32)
    ok = True
    for d in (1, 4, 16):
        for strat in IssueStrategy:
            for rows in (1, 2):
                got = pul_sum(data, trace, rows_per_req=rows,
                              cfg=PULConfig(distance=d, strategy=strat))
                idx = jnp.concatenate([jnp.arange(rows) + t * rows
                                       for t in trace])
                ok &= bool(jnp.allclose(got, ref.sum_ref(data, idx),
                                        rtol=1e-4))
    d2 = jax.random.normal(jax.random.PRNGKey(2), (256, 32), jnp.float32)
    for mat in (False, True):
        got = pul_filter(d2, 0.0, rows_per_block=64, materialize=mat)
        want = (ref.filter_materialize_ref(d2, 0.0) if mat
                else ref.filter_ref(d2, 0.0))
        ok &= bool(jnp.all(got == want))
    claim("kernels.functional-at-all-figure-knobs", ok, "pul_sum/pul_filter")
    row("kernels/functional_sweep", "pass" if ok else "FAIL", "")


def run_all():
    ROWS.clear()
    CLAIMS.clear()
    exp1_roofline()
    exp2_intensity_constant_time()
    exp3_distance()
    exp4_transfer_size()
    exp5_unload()
    kernels_functional_sweep()
    return ROWS, CLAIMS

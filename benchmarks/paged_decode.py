"""Paged decode: assemble-then-attend vs kernel-true page streaming.

  PYTHONPATH=src python benchmarks/paged_decode.py [--arch qwen3-1.7b]
      [--slots 2] [--max-seq 64] [--page-tokens 8] [--requests 4]
      [--max-new 8]

Runs the SAME request mix through the paged serving engine twice:

  * assembly path (``use_paged_kernel=False``): every decode step gathers
    the live slots' pages into a dense (B, S, F) KV view, then attends —
    the oracle path, and what a naive paged engine does;
  * kernel-true path (``use_paged_kernel=True``): attention streams pages
    straight through the PUL preload ring (`pul_paged_decode_attention`),
    the page table serving as the preload trace; no dense view exists.

Reports per-step wall times (CPU interpret mode — relative numbers only),
verifies the two token streams are identical, and quantifies the traffic
the kernel-true path removes: the assembly path materializes the full
B x max_seq x F packed view every step (a write + read of the whole decode
working set), while the ring only reads the pages the step actually needs.
On TPU that materialized copy is real HBM bandwidth; removing it is the
point of driving the kernel from the page table (paper Exp. 2: trace-driven
preload of a scattered working set).
"""
import os
import sys
sys.path.insert(0, "src")

# pin CPU-backend threading before jax loads: this script hard-asserts
# token-stream parity, and threaded-reduction accumulation reorder can flip
# 1-ulp near-tie argmaxes (same rationale as tests/conftest.py)
os.environ.setdefault("OMP_NUM_THREADS", "1")
if "--xla_cpu_multi_thread_eigen" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false").strip()

import argparse
import dataclasses
import statistics
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import PagedEngineConfig, PagedServingEngine, Request


def run_engine(cfg, params, engine_cfg, prompts, max_new):
    snaps = []
    eng = PagedServingEngine(cfg, params, engine_cfg,
                             metrics_hook=snaps.append)
    eng._snaps = snaps
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new))
    times = []
    ticks = 0
    pending = lambda: (len(eng.scheduler)
                       or any(r is not None for r in eng.slot_req))
    while pending() and ticks < 1000:
        t0 = time.perf_counter()
        eng.step()
        times.append(time.perf_counter() - t0)
        ticks += 1
    return eng, times


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(dataclasses.replace(cfg, paged_kv=True))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(3, 24))).tolist()
               for _ in range(args.requests)]

    base = dict(batch_slots=args.slots, max_seq=args.max_seq,
                page_tokens=args.page_tokens, prefill_buckets=(8, 16, 32))
    print(f"{args.arch} (reduced): {args.requests} requests x "
          f"{args.max_new} new tokens, {args.slots} slots, "
          f"pages of {args.page_tokens} tokens")

    results = {}
    for name, kern in (("assemble-then-attend", False), ("kernel-true", True)):
        eng, times = run_engine(cfg, params,
                                PagedEngineConfig(**base,
                                                  use_paged_kernel=kern),
                                prompts, args.max_new)
        out = {rid: r.out_tokens for rid, r in eng.requests.items()}
        steady = times[2:] or times        # drop compile-dominated ticks
        results[name] = (eng, out)
        print(f"\n  {name}:")
        print(f"    ticks {len(times)}, decode steps "
              f"{eng.metrics.decode_steps}, prefills {eng.metrics.prefills}")
        print(f"    per-tick wall: median {statistics.median(steady)*1e3:.1f}"
              f" ms  p90 {np.percentile(steady, 90)*1e3:.1f} ms"
              f"  (first/compile {times[0]*1e3:.0f} ms)")

    (ea, outa), (ek, outk) = results.values()
    print(f"\n  token streams identical: {outa == outk}")
    assert outa == outk, "kernel-true decode diverged from the assembly oracle"

    # traffic the kernel-true path removes (per decode step, modeled): the
    # assembly path materializes the WHOLE decode view; the ring reads only
    # the live working set, and overlaps those reads with compute
    page_bytes = ea.pool.page_bytes
    dense_bytes = args.slots * (args.max_seq // args.page_tokens) * page_bytes
    live_pages = np.mean([s["hot_pages_in_use"] for s in ea._snaps]
                         or [0.0])
    streamed = live_pages * page_bytes
    print(f"  per-step dense view materialized (assembly): "
          f"{dense_bytes/1024:.1f} KiB (gather write + attend read)")
    print(f"  per-step page stream (kernel-true, mean over run): "
          f"{streamed/1024:.1f} KiB read-only through the d* ring")
    print(f"  preload distance d* = {ea.pool.distance}")


if __name__ == "__main__":
    main()

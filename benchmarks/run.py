"""Benchmark entrypoint: one section per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--skip-roofline]

Prints `name,value,derived` CSV rows per figure, the paper-claim PASS/FAIL
lines, and (when dry-run artifacts exist) the roofline table.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    args = ap.parse_args(argv)

    t0 = time.time()
    from benchmarks import paper_experiments as P

    rows, claims = P.run_all()
    print("name,value,derived")
    for r in rows:
        print(r)
    print()
    failed = 0
    for c in claims:
        print(c)
        failed += ("FAIL" in c)
    print(f"\n[bench] {len(rows)} rows, {len(claims)} claims "
          f"({failed} failed) in {time.time()-t0:.1f}s")

    if not args.skip_roofline:
        import glob
        if glob.glob(f"{args.dryrun_dir}/*.json"):
            print("\n=== roofline (from dry-run artifacts) ===")
            from benchmarks import roofline
            roofline.main(["--dryrun-dir", args.dryrun_dir])
        else:
            print("\n[bench] no dry-run artifacts; run "
                  "`python -m repro.launch.dryrun --all` first")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""KV-page DMA benchmark: the paged serving design on the discrete-event twin.

  PYTHONPATH=src python benchmarks/kv_page_dma.py [--tier remote_hbm]
      [--pe tpu_v5e_vpu] [--page-tokens 16] [--kv-features 128] [--gqa 4]
      [--arch qwen3-1.7b [--reduced]]

``--arch`` derives the page geometry (packed KV features/token and the GQA
group) from a real zoo architecture through the serving KV-store layout —
the SAME `KVStoreLayout` the paged engine serves with — instead of the raw
--kv-features/--gqa numbers.

Sweeps the page-restore preload distance on `core.dma`'s KV-page workload
and reports, per distance: modeled restore throughput, PE utilization, and
the fraction of page access latency hidden. The planner's d* row is marked —
at steady state it should hide >=90% of the restore latency (the paper's
claim transplanted to KV paging; tests/test_dma_invariants.py asserts it).
"""
import sys
sys.path.insert(0, "src")

import argparse

from repro.analysis.plan_verifier import diff_fifo_occupancy, verify_kv_page_plan
from repro.core import (
    DMAEngine,
    KVPageWorkload,
    PES,
    PULConfig,
    TIERS,
    kv_page_latency_hidden,
    plan_kv_page_stream,
    run_kv_page_workload,
)
from repro.obs import Tracer, validate_chrome_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", default="remote_hbm", choices=sorted(TIERS))
    ap.add_argument("--pe", default="tpu_v5e_vpu", choices=sorted(PES))
    ap.add_argument("--page-tokens", type=int, default=16)
    ap.add_argument("--kv-features", type=int, default=128)
    ap.add_argument("--gqa", type=int, default=4)
    ap.add_argument("--arch", default=None,
                    help="derive --kv-features/--gqa from a zoo arch's "
                         "KV-store layout (overrides both flags)")
    ap.add_argument("--reduced", action="store_true",
                    help="with --arch: use the reduced config")
    ap.add_argument("--pages-per-step", type=int, default=4)
    ap.add_argument("--steps", type=int, default=256)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="re-run the d* row with a traced DMAEngine, write "
                         "a Chrome/Perfetto trace (descriptor spans, FIFO "
                         "occupancy counters, back-pressure stalls) to "
                         "PATH, and diff the executed occupancy against "
                         "the plan verifier's symbolic schedule — exit 1 "
                         "if they diverge")
    args = ap.parse_args()

    tier, pe = TIERS[args.tier], PES[args.pe]
    P, F, gqa = args.page_tokens, args.kv_features, args.gqa
    if args.arch:
        # real page geometry: ask the serving layout what a page holds
        from repro.configs import get_config
        from repro.serving import PackedKVLayout
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        layout = PackedKVLayout(cfg, 1, P)
        F = max(layout.features, 1)
        gqa = cfg.num_heads // max(cfg.num_kv_heads, 1)
        print(f"arch {args.arch}: layout v{layout.layout_version}, "
              f"{F} packed KV features/token, gqa group {gqa}")
    plan = plan_kv_page_stream(page_tokens=P, kv_features=F, tier=tier,
                               pe=pe, gqa_group=gqa)
    wl = KVPageWorkload(page_bytes=P * F * 2,
                        flops_per_page=4.0 * P * F * gqa,
                        pages_per_step=args.pages_per_step, steps=args.steps)
    # precondition: the planner's output must pass static verification
    # (coverage, issue ordering, FIFO discipline) before anything executes
    report = verify_kv_page_plan(plan, n_pages=wl.n_pages,
                                 page_bytes=wl.page_bytes)
    print(f"plan verified: d*={report.distance}, {report.n_blocks} pages, "
          f"peak in-flight window {report.max_in_flight}"
          + (f" ({len(report.warnings)} warning(s))" if report.warnings
             else ""))
    print(f"KV pages: {P} tok x {F} feat = {wl.page_bytes} B;"
          f" tier={tier.name} pe={pe.name} gqa={gqa}")
    print(f"planner: d*={plan.cfg.distance} ({plan.bound}-bound, predicted "
          f"{plan.predicted_utilization:.0%} PE utilization)\n")
    print(f"{'d':>4} {'time(us)':>10} {'GB/s':>8} {'PE util':>8} "
          f"{'latency hidden':>15}")
    sweep = sorted({1, 2, 4, 8, 16, 32, 64, plan.cfg.distance})
    for d in sweep:
        stats = run_kv_page_workload(DMAEngine(tier, pe), wl, distance=d)
        hidden = kv_page_latency_hidden(DMAEngine(tier, pe), wl, distance=d)
        mark = "  <- d*" if d == plan.cfg.distance else ""
        print(f"{d:>4} {stats.total_time*1e6:>10.1f} "
              f"{stats.io_throughput/1e9:>8.2f} "
              f"{stats.pe_utilization:>7.0%} {hidden:>14.0%}{mark}")
    base = run_kv_page_workload(DMAEngine(tier, pe), wl,
                                distance=plan.cfg.distance, interleave=False)
    star = run_kv_page_workload(DMAEngine(tier, pe), wl,
                                distance=plan.cfg.distance)
    print(f"\ninterleaved vs phase-separated at d*: "
          f"{base.total_time / star.total_time:.2f}x")

    if args.trace:
        tracer = Tracer()
        eng = DMAEngine(tier, pe, tracer=tracer)
        run_kv_page_workload(eng, wl, distance=plan.cfg.distance)
        doc = tracer.to_chrome(args.trace)
        errs = validate_chrome_trace(doc)
        assert not errs, "\n".join(errs)
        print(f"\ntrace: {len(doc['traceEvents'])} events -> {args.trace}")
        # the traced run's executed FIFO occupancy must match the plan
        # verifier's symbolic schedule (same cfg run_kv_page_workload built)
        cfg = PULConfig(distance=min(plan.cfg.distance, eng.fifo_depth),
                        fifo_depth=eng.fifo_depth, unload_distance=1)
        pre, _ = eng.last_channels
        diff = diff_fifo_occupancy(cfg, n_blocks=wl.n_pages, channel=pre,
                                   engine_fifo_depth=eng.fifo_depth)
        if diff:
            print("FIFO occupancy diverges from the symbolic schedule:")
            for line in diff:
                print(f"  {line}")
            return 1
        print(f"FIFO occupancy matches the symbolic schedule "
              f"({len(pre.occupancy_log)} enqueues, high-water "
              f"{pre.max_outstanding} @ t={pre.high_water_time * 1e6:.1f}us)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Roofline report: three terms per (arch x shape x mesh) cell.

Combines the dry-run artifacts (results/dryrun/*.json: memory analysis,
per-device collective wire bytes from the compiled HLO) with the analytic
execution-cost model (benchmarks/flops.py — authoritative for FLOPs/bytes
because XLA's cost_analysis counts scan bodies once; the compiled counter is
still reported as a cross-check).

Hardware constants (TPU v5e per chip):
  peak bf16   197 TFLOP/s
  HBM bw      819 GB/s
  ICI         ~50 GB/s/link

Terms (seconds, per the assignment's formulas — numbers are global/chips):
  compute    = EXEC_FLOPS  / (chips * peak)
  memory     = EXEC_BYTES  / (chips * hbm_bw)
  collective = COLLECTIVE_BYTES / (chips * link_bw)
               with COLLECTIVE_BYTES = per-device wire bytes x chips, so the
               term reduces to per-device bytes / link_bw.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dryrun-dir results/dryrun]
Writes results/roofline.csv and prints the markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import csv
import glob
import json
from pathlib import Path

from repro.configs import CONFIGS, SHAPES, get_config
from benchmarks.flops import cell_cost, active_params, total_params

PEAK_BF16 = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def analyze(dryrun_dir: str):
    rows = []
    for f in sorted(glob.glob(f"{dryrun_dir}/*.json")):
        r = json.load(open(f))
        if r["status"] != "ok":
            if r["status"] == "skipped":
                rows.append({"arch": r["arch"], "shape": r["shape"],
                             "mesh": r["mesh"], "status": "skipped",
                             "note": r.get("reason", "")})
            continue
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        chips = r["chips"]
        cost = cell_cost(cfg, shape, accum=r.get("accum") or 0)
        t_compute = cost.exec_flops / (chips * PEAK_BF16)
        t_memory = cost.exec_bytes / (chips * HBM_BW)
        # collective: HLO bytes, corrected for lax.scan trip counts by
        # while-nesting depth (depth-1 = accum or groups scan, depth-2 =
        # groups scan inside accum; deeper scans get the same cap)
        accum = r.get("accum") or 1
        chunk_trips = max(1, shape.seq_len // max(cfg.chunk_size, 1)) \
            if cfg.ssm_heads and shape.kind != "decode" else 32
        if shape.kind == "train":
            trips = ([accum] if accum > 1 else []) + [cfg.num_groups,
                                                      chunk_trips]
        else:
            trips = [cfg.num_groups, chunk_trips]
        by_depth = r.get("collective_bytes_by_depth",
                         {"0": r["collective_bytes_per_dev"]})
        coll_dev = 0.0
        for depth_s, nb in by_depth.items():
            mult = 1.0
            for d in range(min(int(depth_s), len(trips))):
                mult *= trips[d]
            coll_dev += nb * mult
        t_coll = coll_dev / LINK_BW
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        dominant = max(terms, key=terms.get)
        step_time = max(terms.values())
        # roofline fraction: MFU-style for train/prefill (useful compute vs
        # bottleneck), MBU-style for decode (achieved bandwidth vs HBM peak)
        t_useful = cost.model_flops / (chips * PEAK_BF16)
        if shape.kind == "decode":
            frac = t_memory / step_time if step_time > 0 else 0.0
        else:
            frac = t_useful / step_time if step_time > 0 else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok", "chips": chips,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "roofline_frac": frac,
            "model_flops": cost.model_flops, "exec_flops": cost.exec_flops,
            "useful_ratio": cost.model_flops / cost.exec_flops,
            "exec_bytes": cost.exec_bytes,
            "hlo_flops_per_dev(xcheck)": r["flops_per_dev"],
            "peak_gib_per_dev": r["peak_bytes_per_dev"] / 2**30,
            "accum": r.get("accum"),
        })
    return rows


FIX_HINTS = {
    "compute": "raise useful_ratio: drop MoE einsum dispatch / lighter remat",
    "memory": "cut optimizer+activation traffic: larger microbatch, fp8/int8 "
              "moments, fused optimizer",
    "collective": "reshard to cut all-gathers: sequence-shard saves, overlap "
                  "FSDP gathers across groups (ICI preload)",
}


def to_markdown(rows):
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac | peak GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — |"
                       f" — | skipped | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2%} "
            f"| {r['peak_gib_per_dev']:.1f} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--csv", default="results/roofline.csv")
    args = ap.parse_args(argv)
    rows = analyze(args.dryrun_dir)
    ok = [r for r in rows if r["status"] == "ok"]
    Path(args.csv).parent.mkdir(parents=True, exist_ok=True)
    if ok:
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(ok[0].keys()))
            w.writeheader()
            for r in ok:
                w.writerow(r)
    print(to_markdown(rows))
    # summary: worst cells per criterion (the hillclimb candidates)
    if ok:
        worst_frac = min(ok, key=lambda r: r["roofline_frac"])
        worst_coll = max(ok, key=lambda r: r["t_collective_s"]
                         / max(1e-12, max(r["t_compute_s"], r["t_memory_s"])))
        print(f"\nworst roofline fraction: {worst_frac['arch']} x "
              f"{worst_frac['shape']} x {worst_frac['mesh']} "
              f"({worst_frac['roofline_frac']:.2%}, "
              f"dominant {worst_frac['dominant']})")
        print(f"most collective-bound: {worst_coll['arch']} x "
              f"{worst_coll['shape']} x {worst_coll['mesh']}")
        for r in (worst_frac, worst_coll):
            print(f"  fix hint [{r['dominant']}]: {FIX_HINTS[r['dominant']]}")
    return rows


if __name__ == "__main__":
    main()

"""Roofline report: three terms per (arch x shape x mesh) cell.

Combines the dry-run artifacts (results/dryrun/*.json: memory analysis,
per-device collective wire bytes from the compiled HLO) with the analytic
execution-cost model (benchmarks/flops.py — authoritative for FLOPs/bytes
because XLA's cost_analysis counts scan bodies once; the compiled counter is
still reported as a cross-check).

Hardware constants (TPU v5e per chip):
  peak bf16   197 TFLOP/s
  HBM bw      819 GB/s
  ICI         ~50 GB/s/link

Terms (seconds, per the assignment's formulas — numbers are global/chips):
  compute    = EXEC_FLOPS  / (chips * peak)
  memory     = EXEC_BYTES  / (chips * hbm_bw)
  collective = COLLECTIVE_BYTES / (chips * link_bw)
               with COLLECTIVE_BYTES = per-device wire bytes x chips, so the
               term reduces to per-device bytes / link_bw.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dryrun-dir results/dryrun]
Writes results/roofline.csv and prints the markdown table for EXPERIMENTS.md.

Serving mode (``--serving``) turns the same roofline constants on the paged
serving engine instead of the dry-run artifacts: it runs a small
deterministic workload (with one preempt/resume, so the cold tier actually
moves bytes) through the fused-sweep paged decode path, computes each KV
tier's achieved-vs-peak bandwidth fraction from the pool's tick-exact byte
counters (``repro.obs.serving_roofline`` — modeled, NOT wall time), merges
the report into ``BENCH_serving.json``, and gates the fractions against
``benchmarks/baselines/roofline_serving.json`` the same way serving_slo.py
gates TTFT. Exits non-zero on a gate failure so CI can enforce it.
"""
from __future__ import annotations

import os
import sys

# pin CPU-backend threading before jax loads (serving mode only needs it,
# but env must be set before any repro import pulls jax in)
os.environ.setdefault("OMP_NUM_THREADS", "1")
if "--xla_cpu_multi_thread_eigen" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false").strip()

import argparse
import csv
import glob
import json
from pathlib import Path

from repro.configs import CONFIGS, SHAPES, get_config
from benchmarks.flops import cell_cost, active_params, total_params

PEAK_BF16 = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def analyze(dryrun_dir: str):
    rows = []
    for f in sorted(glob.glob(f"{dryrun_dir}/*.json")):
        r = json.load(open(f))
        if r["status"] != "ok":
            if r["status"] == "skipped":
                rows.append({"arch": r["arch"], "shape": r["shape"],
                             "mesh": r["mesh"], "status": "skipped",
                             "note": r.get("reason", "")})
            continue
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        chips = r["chips"]
        cost = cell_cost(cfg, shape, accum=r.get("accum") or 0)
        t_compute = cost.exec_flops / (chips * PEAK_BF16)
        t_memory = cost.exec_bytes / (chips * HBM_BW)
        # collective: HLO bytes, corrected for lax.scan trip counts by
        # while-nesting depth (depth-1 = accum or groups scan, depth-2 =
        # groups scan inside accum; deeper scans get the same cap)
        accum = r.get("accum") or 1
        chunk_trips = max(1, shape.seq_len // max(cfg.chunk_size, 1)) \
            if cfg.ssm_heads and shape.kind != "decode" else 32
        if shape.kind == "train":
            trips = ([accum] if accum > 1 else []) + [cfg.num_groups,
                                                      chunk_trips]
        else:
            trips = [cfg.num_groups, chunk_trips]
        by_depth = r.get("collective_bytes_by_depth",
                         {"0": r["collective_bytes_per_dev"]})
        coll_dev = 0.0
        for depth_s, nb in by_depth.items():
            mult = 1.0
            for d in range(min(int(depth_s), len(trips))):
                mult *= trips[d]
            coll_dev += nb * mult
        t_coll = coll_dev / LINK_BW
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        dominant = max(terms, key=terms.get)
        step_time = max(terms.values())
        # roofline fraction: MFU-style for train/prefill (useful compute vs
        # bottleneck), MBU-style for decode (achieved bandwidth vs HBM peak)
        t_useful = cost.model_flops / (chips * PEAK_BF16)
        if shape.kind == "decode":
            frac = t_memory / step_time if step_time > 0 else 0.0
        else:
            frac = t_useful / step_time if step_time > 0 else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok", "chips": chips,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "roofline_frac": frac,
            "model_flops": cost.model_flops, "exec_flops": cost.exec_flops,
            "useful_ratio": cost.model_flops / cost.exec_flops,
            "exec_bytes": cost.exec_bytes,
            "hlo_flops_per_dev(xcheck)": r["flops_per_dev"],
            "peak_gib_per_dev": r["peak_bytes_per_dev"] / 2**30,
            "accum": r.get("accum"),
        })
    return rows


# ---------------------------------------------------------------------- #
# serving mode: achieved-vs-peak bandwidth per KV tier
# ---------------------------------------------------------------------- #
# which peak each pool tier rooflines against: the hot tier is device HBM,
# the cold (spill) tier crosses the interconnect
SERVING_TIER_BW = {"hot": HBM_BW, "cold": LINK_BW}

# metrics a baselines/roofline_serving.json entry may gate, by key
_SERVING_METRICS = {
    "hot_bw_fraction": lambda r: r["tiers"]["hot"]["bw_fraction"],
    "cold_bw_fraction": lambda r: r["tiers"]["cold"]["bw_fraction"],
    "hot_bytes_per_token": lambda r: r["tiers"]["hot"]["bytes_per_token"],
    "cold_bytes_per_token": lambda r: r["tiers"]["cold"]["bytes_per_token"],
}


def run_serving(arch: str, steps: int = 160, use_kernel: bool = True):
    """Run the deterministic serving workload; return the roofline report.

    Mirrors examples/serve_lm.py's shape: 6 requests over 3 slots with a
    shared-prefix pair, warm-up decode, then one preempt/resume so
    evictions + restores (the cold tier's traffic) appear in the counters.
    """
    import dataclasses

    import jax
    import numpy as np

    from repro.models import build_model
    from repro.obs import serving_roofline
    from repro.serving import PagedServingEngine, Request, ServingConfig

    cfg = get_config(arch).reduced()
    model = build_model(dataclasses.replace(cfg, paged_kv=True))
    params = model.init(jax.random.PRNGKey(0))
    eng = PagedServingEngine(cfg, params, ServingConfig(
        batch_slots=3, max_seq=96, page_tokens=8,
        prefill_buckets=(8, 16, 32), use_paged_kernel=use_kernel))

    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, size=8).tolist()
    for i in range(6):
        if i < 2:
            prompt = shared + rng.integers(
                1, cfg.vocab_size, size=rng.integers(1, 6)).tolist()
        else:
            prompt = rng.integers(1, cfg.vocab_size,
                                  size=rng.integers(3, 12)).tolist()
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=10))
    for _ in range(4):
        eng.step()
    victim = next((i for i, r in enumerate(eng.slot_req) if r is not None),
                  None)
    if victim is not None:
        eng.preempt(victim)
        eng.step()
        eng.resume(victim)
    out = eng.run(max_ticks=steps)
    assert all(len(v) == 10 for v in out.values()), \
        "serving workload did not finish every request"
    assert eng.pool.metrics.evictions >= 1 and \
        eng.pool.metrics.page_faults >= 1, \
        "preempt/resume moved no bytes through the cold tier"

    n_params = int(sum(x.size for x in jax.tree_util.tree_leaves(params)))
    roof = serving_roofline(econ=eng.economics(), n_params=n_params,
                            tokens_emitted=eng.metrics.tokens_emitted,
                            peak_flops=PEAK_BF16, hot_bw=HBM_BW,
                            cold_bw=LINK_BW)
    roof["arch"] = arch
    roof["steps"] = steps
    roof["paged_kernel"] = use_kernel
    roof["sweep_decode"] = bool(use_kernel and eng.cfg.sweep_decode)
    return roof


def evaluate_serving_gate(roof, baseline_path):
    """Gate serving roofline metrics against checked-in baselines.

    Every metric here is counter-derived and deterministic, so the gate is
    a two-sided band: measured must sit within [baseline / threshold,
    baseline * threshold]. Above-band = traffic regression (e.g. a copy
    crept back into the zero-copy path, or the fused commit double-writes);
    below-band = the byte accounting itself broke.
    """
    with open(baseline_path) as f:
        base = json.load(f)
    checks = []
    for metric, spec in sorted(base.items()):
        if metric.startswith("_"):      # _comment etc.
            continue
        measured = _SERVING_METRICS[metric](roof)
        lo = spec["baseline"] / spec["threshold"]
        hi = spec["baseline"] * spec["threshold"]
        checks.append({
            "metric": metric,
            "measured": measured,
            "baseline": spec["baseline"],
            "threshold": spec["threshold"],
            "pass": lo <= measured <= hi,
        })
    return {
        "baseline": baseline_path,
        "checks": checks,
        "pass": all(c["pass"] for c in checks),
    }


def _merge_serving_report(out_path, roof, gate):
    """Merge roofline + gate into BENCH_serving.json, preserving whatever
    serving_slo.py already wrote there."""
    report = {"benchmark": "serving_roofline"}
    if os.path.exists(out_path):
        with open(out_path) as f:
            report = json.load(f)
    report["roofline"] = roof
    report["roofline_gate"] = gate
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)


def serving_main(args):
    roof = run_serving(args.serving_arch, steps=args.serving_steps,
                       use_kernel=not args.serving_no_kernel)
    m = roof["modeled"]
    print(f"serving roofline [{roof['arch']}, "
          f"{'fused sweep' if roof['sweep_decode'] else 'reference path'}]: "
          f"{roof['tokens_emitted']} tokens, critical path "
          f"{m['critical_path_s'] * 1e6:.1f}us ({m['dominant']}-bound)")
    for tier, t in roof["tiers"].items():
        print(f"  {tier:>4}: {t['bytes_moved']:>9} B moved "
              f"({t['bytes_per_token']:.0f} B/tok), achieved "
              f"{t['achieved_bw'] / 1e9:.2f} GB/s of "
              f"{t['peak_bw'] / 1e9:.0f} GB/s peak "
              f"= {t['bw_fraction']:.2%}")
    gate = evaluate_serving_gate(roof, args.serving_baseline)
    _merge_serving_report(args.out, roof, gate)
    print(f"wrote {args.out}")
    for c in gate["checks"]:
        status = "PASS" if c["pass"] else "FAIL"
        print(f"   gate {c['metric']}: {c['measured']:.4g} vs baseline "
              f"{c['baseline']:.4g} (band {c['threshold']}x) [{status}]")
    if not gate["pass"]:
        print("serving roofline gate: FAIL")
        return 1
    print("serving roofline gate: PASS")
    return 0


FIX_HINTS = {
    "compute": "raise useful_ratio: drop MoE einsum dispatch / lighter remat",
    "memory": "cut optimizer+activation traffic: larger microbatch, fp8/int8 "
              "moments, fused optimizer",
    "collective": "reshard to cut all-gathers: sequence-shard saves, overlap "
                  "FSDP gathers across groups (ICI preload)",
}


def to_markdown(rows):
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac | peak GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — |"
                       f" — | skipped | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2%} "
            f"| {r['peak_gib_per_dev']:.1f} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--csv", default="results/roofline.csv")
    ap.add_argument("--serving", action="store_true",
                    help="roofline the paged serving engine's KV tiers "
                         "instead of the dry-run artifacts; gates vs "
                         "--serving-baseline and merges into --out")
    ap.add_argument("--serving-arch", default="qwen3-1.7b")
    ap.add_argument("--serving-steps", type=int, default=160)
    ap.add_argument("--serving-no-kernel", action="store_true",
                    help="measure the reference (non-fused) paged path")
    ap.add_argument("--serving-baseline",
                    default="benchmarks/baselines/roofline_serving.json")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="serving mode: BENCH JSON to merge the roofline "
                         "report into")
    args = ap.parse_args(argv)
    if args.serving:
        sys.exit(serving_main(args))
    rows = analyze(args.dryrun_dir)
    ok = [r for r in rows if r["status"] == "ok"]
    Path(args.csv).parent.mkdir(parents=True, exist_ok=True)
    if ok:
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(ok[0].keys()))
            w.writeheader()
            for r in ok:
                w.writerow(r)
    print(to_markdown(rows))
    # summary: worst cells per criterion (the hillclimb candidates)
    if ok:
        worst_frac = min(ok, key=lambda r: r["roofline_frac"])
        worst_coll = max(ok, key=lambda r: r["t_collective_s"]
                         / max(1e-12, max(r["t_compute_s"], r["t_memory_s"])))
        print(f"\nworst roofline fraction: {worst_frac['arch']} x "
              f"{worst_frac['shape']} x {worst_frac['mesh']} "
              f"({worst_frac['roofline_frac']:.2%}, "
              f"dominant {worst_frac['dominant']})")
        print(f"most collective-bound: {worst_coll['arch']} x "
              f"{worst_coll['shape']} x {worst_coll['mesh']}")
        for r in (worst_frac, worst_coll):
            print(f"  fix hint [{r['dominant']}]: {FIX_HINTS[r['dominant']]}")
    return rows


if __name__ == "__main__":
    main()

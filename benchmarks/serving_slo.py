"""Serving-policy SLO benchmark: fcfs vs priority vs slo-edf.

  PYTHONPATH=src python benchmarks/serving_slo.py [--arch qwen3-1.7b]
      [--steps 64] [--out BENCH_serving.json]
      [--baseline benchmarks/baselines/serving.json]

Runs the SAME mixed workload through the paged serving engine once per
scheduling policy: two long low-priority decoders grab both slots first,
then three short high-priority requests (tight TTFT deadline, in ticks)
arrive behind them. Under ``fcfs`` the shorts head-of-line-block until the
longs drain — every deadline blows. ``priority`` preempts a long per short
immediately; ``slo-edf`` preempts only the requests whose deadline the
lookahead says cannot be met by waiting. Preempted requests swap out to the
cold tier and later resume mid-decode (their restores are the page-fault /
eviction counts below) — the serving-layer analogue of the paper's point:
knowing WHICH pages to move EARLY enough is what hides the latency.

Emits the ``BENCH_serving.json`` contract (per-policy throughput,
preemption counts, TTFT percentiles in ticks, high-priority violation
counts, and a gate vs ``benchmarks/baselines/serving.json``) and exits
non-zero if the contract or the gate fails, so CI can enforce both.

Contract (hard-asserted):
  * every policy finishes the full workload (identical token totals);
  * fcfs has >= 1 high-priority SLO violation, priority and slo-edf have 0;
  * slo-edf's high-priority TTFT p99 is STRICTLY better than fcfs's;
  * the baseline gate passes (throughput floor, TTFT-p99 ceiling).
"""
import os
import sys
sys.path.insert(0, "src")
sys.path.insert(0, ".")     # for benchmarks.roofline (run from repo root)

# pin CPU-backend threading before jax loads (same rationale as
# tests/conftest.py: keep token streams and tick counts deterministic)
os.environ.setdefault("OMP_NUM_THREADS", "1")
if "--xla_cpu_multi_thread_eigen" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false").strip()

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from benchmarks.roofline import HBM_BW, LINK_BW, PEAK_BF16
from repro.configs import get_config
from repro.models import build_model
from repro.obs import Tracer, serving_roofline, validate_chrome_trace
from repro.serving import (
    POLICIES,
    PagedEngineConfig,
    PagedServingEngine,
    Request,
    mean,
    percentile,
)

# tiny-config workload: small enough for the CPU CI job, adversarial
# enough that fcfs provably blows every short request's deadline
WORKLOAD = dict(
    slots=2, max_seq=64, page_tokens=8, buckets=(8, 16, 32),
    long_requests=2, long_prompt=24, long_new=18,
    short_requests=3, short_prompt=6, short_new=4,
    ttft_deadline=6,
    warmup_ticks=2,        # longs decode this many ticks before shorts land
)


def _prompts(vocab):
    rng = np.random.default_rng(1234)
    longs = [rng.integers(1, vocab, size=WORKLOAD["long_prompt"]).tolist()
             for _ in range(WORKLOAD["long_requests"])]
    shorts = [rng.integers(1, vocab, size=WORKLOAD["short_prompt"]).tolist()
              for _ in range(WORKLOAD["short_requests"])]
    return longs, shorts


def run_policy(cfg, params, policy, steps, trace_path=None, n_params=0):
    w = WORKLOAD
    tracer = Tracer() if trace_path else None
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=w["slots"], max_seq=w["max_seq"],
        page_tokens=w["page_tokens"], prefill_buckets=w["buckets"],
        policy=policy), tracer=tracer)
    longs, shorts = _prompts(cfg.vocab_size)
    for i, p in enumerate(longs):
        eng.submit(Request(rid=i, prompt=list(p),
                           max_new_tokens=w["long_new"], priority=0))
    for _ in range(w["warmup_ticks"]):
        eng.step()
    hi_reqs = []
    for j, p in enumerate(shorts):
        r = Request(rid=100 + j, prompt=list(p),
                    max_new_tokens=w["short_new"], priority=1,
                    ttft_deadline=w["ttft_deadline"])
        hi_reqs.append(r)
        eng.submit(r)
    all_reqs = list(eng.requests.values())
    t0 = time.perf_counter()
    eng.run(max_ticks=steps)
    wall = time.perf_counter() - t0

    m, pm = eng.metrics, eng.pool.metrics
    ttfts = [r.ttft for r in all_reqs if r.ttft >= 0]
    hi_ttfts = [r.ttft for r in hi_reqs]
    assert all(t >= 0 for t in hi_ttfts), \
        f"{policy}: a high-priority request never emitted its first token"
    expected = (w["long_requests"] * w["long_new"]
                + w["short_requests"] * w["short_new"])
    assert m.tokens_emitted == expected, \
        f"{policy}: emitted {m.tokens_emitted}, expected {expected}"
    if trace_path:
        doc = tracer.to_chrome(trace_path)
        errs = validate_chrome_trace(doc)
        assert not errs, f"{policy} trace: " + "; ".join(errs)
        print(f"   trace: {len(doc['traceEvents'])} events -> {trace_path}")
    return {
        "policy": policy,
        "wall_time_s": wall,
        "ticks": m.ticks,
        "tokens_emitted": m.tokens_emitted,
        "tokens_per_sec": m.tokens_emitted / wall if wall > 0 else 0.0,
        "prefills": m.prefills,
        "preemptions": m.preemptions,
        "readmissions": m.readmissions,
        "slo_violations": m.slo_violations,
        "page_faults": pm.page_faults,
        "evictions": pm.evictions,
        "mean_queue_latency_ticks": mean(eng.scheduler.queue_latencies()),
        "ttft_p50_ticks": percentile(ttfts, 50),
        "ttft_p99_ticks": percentile(ttfts, 99),
        "high_priority": {
            "ttft_ticks": hi_ttfts,
            "ttft_p50_ticks": percentile(hi_ttfts, 50),
            "ttft_p99_ticks": percentile(hi_ttfts, 99),
            "violations": sum(1 for t in hi_ttfts
                              if t > WORKLOAD["ttft_deadline"]),
        },
        "cache_economics": eng.economics(),
        # achieved-vs-peak bandwidth per KV tier over this policy's run —
        # counter-derived and deterministic (see benchmarks/roofline.py
        # --serving for the gated variant of the same accounting)
        "roofline": serving_roofline(
            econ=eng.economics(), n_params=n_params,
            tokens_emitted=m.tokens_emitted, peak_flops=PEAK_BF16,
            hot_bw=HBM_BW, cold_bw=LINK_BW),
    }


def evaluate_gate(policies, baseline_path):
    """Gate the slo-edf run against checked-in floors/ceilings.

    tokens_per_sec passes when measured >= baseline / threshold (a
    threshold-x slack throughput floor — CI machines are slow and shared);
    TTFT p99 passes when measured <= baseline * threshold (a latency
    ceiling). Tick-derived numbers are deterministic; only wall-clock
    throughput needs the wide slack.
    """
    with open(baseline_path) as f:
        base = json.load(f)
    edf = policies["slo-edf"]
    checks = []
    spec = base["tokens_per_sec"]
    checks.append({
        "metric": "tokens_per_sec",
        "measured": edf["tokens_per_sec"],
        "baseline": spec["baseline"],
        "threshold": spec["threshold"],
        "pass": edf["tokens_per_sec"] >= spec["baseline"] / spec["threshold"],
    })
    spec = base["high_priority_ttft_p99_ticks"]
    measured = edf["high_priority"]["ttft_p99_ticks"]
    checks.append({
        "metric": "high_priority_ttft_p99_ticks",
        "measured": measured,
        "baseline": spec["baseline"],
        "threshold": spec["threshold"],
        "pass": measured <= spec["baseline"] * spec["threshold"],
    })
    return {
        "baseline": baseline_path,
        "checks": checks,
        "pass": all(c["pass"] for c in checks),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/serving.json")
    ap.add_argument("--trace-dir", metavar="DIR", default=None,
                    help="also write a Chrome/Perfetto trace per policy to "
                         "DIR/trace_<policy>.json (feed two of them to "
                         "tools/trace_diff.py to see where the policies' "
                         "decision streams diverge)")
    args = ap.parse_args()
    if args.trace_dir:
        os.makedirs(args.trace_dir, exist_ok=True)

    cfg = get_config(args.arch).reduced()
    model = build_model(dataclasses.replace(cfg, paged_kv=True))
    params = model.init(jax.random.PRNGKey(0))
    n_params = int(sum(x.size for x in jax.tree_util.tree_leaves(params)))

    policies = {}
    for policy in POLICIES:
        print(f"== {policy} ==")
        trace = (os.path.join(args.trace_dir, f"trace_{policy}.json")
                 if args.trace_dir else None)
        policies[policy] = run_policy(cfg, params, policy, args.steps,
                                      trace_path=trace, n_params=n_params)
        p = policies[policy]
        hot = p["cache_economics"]["tiers"]["hot"]
        print(f"   ticks={p['ticks']} tok/s={p['tokens_per_sec']:.2f} "
              f"preempt={p['preemptions']} "
              f"hp_ttft={p['high_priority']['ttft_ticks']} "
              f"hp_violations={p['high_priority']['violations']} "
              f"hot_B/tok={hot['bytes_per_token']:.0f} "
              f"hot_bw={p['roofline']['tiers']['hot']['bw_fraction']:.0%}")

    failures = []
    if policies["fcfs"]["high_priority"]["violations"] < 1:
        failures.append("fcfs shows no SLO violations — workload is not "
                        "adversarial enough to distinguish policies")
    for pol in ("priority", "slo-edf"):
        if policies[pol]["high_priority"]["violations"] != 0:
            failures.append(f"{pol} missed a high-priority deadline")
    p99_fcfs = policies["fcfs"]["high_priority"]["ttft_p99_ticks"]
    p99_edf = policies["slo-edf"]["high_priority"]["ttft_p99_ticks"]
    if not p99_edf < p99_fcfs:
        failures.append(f"slo-edf hp TTFT p99 ({p99_edf}) not strictly "
                        f"better than fcfs ({p99_fcfs})")

    gate = evaluate_gate(policies, args.baseline)
    report = {
        "benchmark": "serving_slo",
        "arch": args.arch,
        "config": {
            "steps": args.steps,
            "slots": WORKLOAD["slots"],
            "max_seq": WORKLOAD["max_seq"],
            "long_requests": WORKLOAD["long_requests"],
            "long_prompt": WORKLOAD["long_prompt"],
            "long_new": WORKLOAD["long_new"],
            "short_requests": WORKLOAD["short_requests"],
            "short_prompt": WORKLOAD["short_prompt"],
            "short_new": WORKLOAD["short_new"],
            "ttft_deadline": WORKLOAD["ttft_deadline"],
        },
        "policies": policies,
        "comparison": {
            "high_priority_ttft_p99_fcfs": p99_fcfs,
            "high_priority_ttft_p99_slo_edf": p99_edf,
            "slo_edf_strictly_better": p99_edf < p99_fcfs,
        },
        "gate": gate,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")

    for c in gate["checks"]:
        status = "PASS" if c["pass"] else "FAIL"
        print(f"   gate {c['metric']}: {c['measured']:.3g} vs baseline "
              f"{c['baseline']} (threshold {c['threshold']}x) [{status}]")
    for msg in failures:
        print(f"CONTRACT FAIL: {msg}")
    if failures or not gate["pass"]:
        sys.exit(1)
    print("serving SLO contract + gate: PASS")


if __name__ == "__main__":
    main()

#!/usr/bin/env python
"""Run the PUL jit-safety lint over the codebase.

  PYTHONPATH=src python tools/run_lint.py [paths...]   # default: src/repro

Exits nonzero if any unwaived finding remains. Waive an intended pattern
inline with `# pul-lint: disable=PUL101` on the flagged line.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.lint import RULES, lint_paths


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to lint (default: src/repro)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args()

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    findings = lint_paths([Path(p) for p in args.paths])
    for f in findings:
        print(f.describe())
    if findings:
        print(f"\n{len(findings)} finding(s). Fix, or waive intended lines "
              "with `# pul-lint: disable=<rule>`.", file=sys.stderr)
        return 1
    print(f"pul-lint: clean ({', '.join(args.paths)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

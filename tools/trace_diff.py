#!/usr/bin/env python
"""Explain why two PUL serving traces diverged.

  PYTHONPATH=src python tools/trace_diff.py a.json b.json
      [--expect-diverge | --expect-match]

Two runs of the same request stream can take different eviction/admission
paths (different policy, hot-tier size, preload distance, ...). This tool
aligns the *decision streams* of two traces — scheduler decisions (admit /
reject / admission-blocked / preempt / resume, each carrying its
machine-readable reason) interleaved with page evict/restore lifecycle
events — and reports the FIRST point where they diverge, with both sides'
full arguments. That first divergence is the causal one: everything after
it runs on different engine state.

Volatile keys (``seq``, ``clock``, ``tick`` — positions in the trace, not
decisions) are excluded from equality but kept in the report.

Exit codes: 0 = the comparison matched the expectation (``--expect-*``), or
no expectation was given; 1 = expectation violated. The CI trace-smoke
golden test runs two eviction policies over one request stream and requires
``--expect-diverge`` to find a reasoned divergence.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs.tracer import load_chrome_trace

# trace positions, not decisions: two runs that decide identically still
# reach each decision at different ticks/seqs
VOLATILE_KEYS = ("seq", "clock", "tick")

# page-lifecycle kinds that change future eviction/admission behavior
# (TOUCH/READ/WRITE noise would drown the comparison in LRU bookkeeping)
PAGE_KINDS = ("evict", "restore")


def decision_stream(doc):
    """The trace's decision events + page evict/restore events, in file
    order (the tracer appends in program order). Each item is
    (label, comparable_args, full_args)."""
    out = []
    for ev in doc.get("traceEvents", ()):
        if ev.get("ph") != "i":
            continue
        cat = ev.get("cat")
        name = ev.get("name", "")
        if cat == "decision" or (cat == "page" and name in PAGE_KINDS):
            args = dict(ev.get("args") or {})
            comparable = {k: v for k, v in args.items()
                          if k not in VOLATILE_KEYS}
            out.append((f"{cat}:{name}", comparable, args))
    return out


def _fmt(item):
    label, _, full = item
    args = ", ".join(f"{k}={v}" for k, v in sorted(full.items()))
    return f"{label}({args})"


def diff_decisions(a, b):
    """First divergence between two decision streams, or None.

    Returns (index, explanation) — the explanation names what differs and
    why it matters (the reason argument when one is present)."""
    for i, (x, y) in enumerate(zip(a, b)):
        if x[0] != y[0] or x[1] != y[1]:
            why = []
            if x[0] != y[0]:
                why.append(f"different event kinds: {x[0]} vs {y[0]}")
            else:
                keys = sorted(set(x[1]) | set(y[1]))
                for k in keys:
                    if x[1].get(k) != y[1].get(k):
                        why.append(f"{k}: {x[1].get(k)!r} vs {y[1].get(k)!r}")
            ra, rb = x[1].get("reason"), y[1].get("reason")
            reason = ra or rb
            if reason:
                why.append(f"reason: {ra!r} vs {rb!r}" if ra != rb
                           else f"reason: {reason!r}")
            return i, (f"decision #{i} diverges — {'; '.join(why)}\n"
                       f"  A: {_fmt(x)}\n  B: {_fmt(y)}")
    if len(a) != len(b):
        i = min(len(a), len(b))
        longer, item = ("A", a[i]) if len(a) > len(b) else ("B", b[i])
        return i, (f"streams agree for {i} decisions, then {longer} "
                   f"continues alone:\n  {longer}: {_fmt(item)}")
    return None


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace_a")
    ap.add_argument("trace_b")
    ap.add_argument("--expect-diverge", action="store_true",
                    help="exit 1 unless a divergence is found")
    ap.add_argument("--expect-match", action="store_true",
                    help="exit 1 if any divergence is found")
    args = ap.parse_args()

    a = decision_stream(load_chrome_trace(args.trace_a))
    b = decision_stream(load_chrome_trace(args.trace_b))
    print(f"A: {len(a)} decision/page events ({args.trace_a})")
    print(f"B: {len(b)} decision/page events ({args.trace_b})")

    found = diff_decisions(a, b)
    if found is None:
        print("decision streams are identical")
        return 1 if args.expect_diverge else 0
    _, explanation = found
    print(explanation)
    return 1 if args.expect_match else 0


if __name__ == "__main__":
    sys.exit(main())

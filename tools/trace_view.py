#!/usr/bin/env python
"""Terminal summary of a PUL Chrome/Perfetto trace.

  PYTHONPATH=src python tools/trace_view.py trace.json [--validate] [--limit N]

Prints, per track: span counts and total/self durations, counter ranges,
decision tallies, and a short timeline of the first events — enough to see
what a serving run did without leaving the terminal (load the same file in
https://ui.perfetto.dev for the full picture).

``--validate`` schema-checks the file first (the contract Perfetto relies
on: known phases, finite timestamps, balanced B/E per thread, paired async
spans) and exits nonzero on any violation — the CI trace-smoke job runs
this against a freshly produced benchmark trace.
"""
import argparse
import sys
from collections import defaultdict
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs.tracer import load_chrome_trace, validate_chrome_trace


def _track_names(doc):
    """(pid, tid) -> track name, from the thread_name metadata."""
    names = {}
    for ev in doc.get("traceEvents", ()):
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    return names


def summarize(doc, limit: int = 12) -> str:
    names = _track_names(doc)
    events = [e for e in doc.get("traceEvents", ()) if e.get("ph") != "M"]
    lines = [f"{len(events)} events across {len(names)} tracks"]

    # synchronous + complete spans: duration per (track, name)
    spans = defaultdict(lambda: [0, 0.0])       # (track, name) -> [n, dur]
    open_b = {}
    counters = defaultdict(lambda: [float("inf"), float("-inf"), 0])
    decisions = defaultdict(int)
    instants = defaultdict(int)
    async_n = defaultdict(int)
    for ev in events:
        track = names.get((ev.get("pid"), ev.get("tid")), "?")
        key = (track, ev.get("name", ""))
        ph = ev.get("ph")
        if ph == "B":
            open_b.setdefault(key, []).append(ev["ts"])
        elif ph == "E":
            # E events may carry an empty name; close the innermost open
            # span on this track instead
            cands = [k for k in open_b if k[0] == track and open_b[k]]
            if key in open_b and open_b[key]:
                cands = [key]
            if cands:
                k = cands[-1]
                t0 = open_b[k].pop()
                spans[k][0] += 1
                spans[k][1] += ev["ts"] - t0
        elif ph == "X":
            spans[key][0] += 1
            spans[key][1] += ev.get("dur", 0.0)
        elif ph == "C":
            for v in (ev.get("args") or {}).values():
                if isinstance(v, (int, float)):
                    c = counters[key]
                    c[0] = min(c[0], v)
                    c[1] = max(c[1], v)
                    c[2] += 1
        elif ph == "i":
            if ev.get("cat") == "decision":
                args = ev.get("args") or {}
                reason = args.get("reason", "")
                label = ev["name"] + (f" [{reason}]" if reason else "")
                decisions[label] += 1
            else:
                instants[key] += 1
        elif ph in ("b", "e"):
            async_n[(track, ev.get("cat", "async"))] += 1

    if spans:
        lines.append("\nspans (track / name: count, total ms):")
        for (track, name), (n, dur) in sorted(
                spans.items(), key=lambda kv: -kv[1][1])[:limit]:
            lines.append(f"  {track:<14} {name:<24} x{n:<6} {dur / 1e3:.3f}")
    if counters:
        lines.append("\ncounters (track / name: samples, min..max):")
        for (track, name), (lo, hi, n) in sorted(counters.items()):
            lines.append(f"  {track:<14} {name:<24} x{n:<6} {lo:g}..{hi:g}")
    if decisions:
        lines.append("\nscheduler decisions:")
        for label, n in sorted(decisions.items()):
            lines.append(f"  {label:<40} x{n}")
    if instants:
        lines.append("\ninstants (track / name: count):")
        for (track, name), n in sorted(instants.items())[:limit]:
            lines.append(f"  {track:<14} {name:<24} x{n}")
    if async_n:
        lines.append("\nasync span events (track / cat: begin+end count):")
        for (track, cat), n in sorted(async_n.items()):
            lines.append(f"  {track:<14} {cat:<24} x{n}")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the trace; exit 1 on any violation")
    ap.add_argument("--limit", type=int, default=12,
                    help="rows per summary table (default 12)")
    args = ap.parse_args()

    doc = load_chrome_trace(args.trace)
    if args.validate:
        errors = validate_chrome_trace(doc)
        if errors:
            for e in errors:
                print(f"SCHEMA: {e}", file=sys.stderr)
            print(f"{args.trace}: {len(errors)} schema violation(s)",
                  file=sys.stderr)
            return 1
        print(f"{args.trace}: schema ok")
    print(summarize(doc, limit=args.limit))
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Unified trace/metrics layer: round-trips, exporters, and the FIFO diff.

Five families:

  * tracer unit tests: Chrome export schema, the NullTracer's zero-cost
    contract, JSON-safety of args (tuples, non-finite floats);
  * schema-validator fixtures: one hand-built broken trace per rule the
    validator enforces (unbalanced B/E, unknown phase, async e-before-b,
    negative X duration, counter without numerics, non-finite ts);
  * engine round-trip (reduced zoo model): a traced serving run with a
    preemption exports a valid trace; the page-lifecycle bridge
    reconstructs the pool's own ``TraceLog`` EXACTLY and replays clean
    through the sanitizer; tracing OFF records nothing and leaves the
    run's deterministic outputs bit-identical; a crashing ``metrics_hook``
    warns once, is disabled, and never kills the tick loop;
  * cache economics + registry: bytes-per-token arithmetic against
    hand-built PoolMetrics, Prometheus/JSON exporter shape, the engine's
    own ``metrics_registry()``;
  * DMA FIFO diff: the executed occupancy of a traced ``run_stream``
    matches the plan verifier's symbolic schedule (clean and
    back-pressure cases), and a corrupted occupancy log is caught.
"""
import dataclasses
import math
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.analysis import LifecycleChecker, diff_fifo_occupancy
from repro.configs import get_config
from repro.core import (
    DMAEngine,
    KVPageWorkload,
    PES,
    PULConfig,
    TIERS,
    run_kv_page_workload,
)
from repro.models import build_model
from repro.obs import (
    MetricsRegistry,
    NULL_TRACER,
    Tracer,
    cache_economics,
    economics_into_registry,
    page_events_from_chrome,
    validate_chrome_trace,
)
from repro.serving import PagedEngineConfig, PagedServingEngine, Request
from repro.serving.kv_pages import PoolMetrics

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
import trace_diff  # noqa: E402  (tools/ is not a package)

pytestmark = pytest.mark.obs


# ======================================================================== #
# tracer unit tests
# ======================================================================== #

def test_export_schema_and_phase_shapes(tmp_path):
    t = Tracer()
    t.set_tick(3)
    with t.span("engine", "tick"):
        t.counter("gauges", "live_slots", 2)
        t.decision("admit", rid=0, reason="capacity")
        t.async_begin("requests", "req0", 0, cat="request")
    t.async_end("requests", "req0", 0, cat="request")
    t.complete("dma/preload", "PRELOAD", ts=1.0, dur=2.5, cat="dma")

    path = tmp_path / "t.json"
    doc = t.to_chrome(str(path))
    assert path.exists()
    assert validate_chrome_trace(doc) == []

    by_ph = {}
    for ev in doc["traceEvents"]:
        by_ph.setdefault(ev["ph"], []).append(ev)
    # counters stay pure: no tick injected next to the value series
    assert all("tick" not in (ev.get("args") or {}) for ev in by_ph["C"])
    # non-counter events carry the tick
    assert all(ev["args"]["tick"] == 3 for ev in by_ph["B"])
    # instants carry thread scope (Perfetto renders them as arrows without)
    assert all(ev["s"] == "t" for ev in by_ph["i"])
    # the DMA track lives in its own process (model time != wall time)
    serving_pids = {ev["pid"] for ev in by_ph["B"]}
    dma_pids = {ev["pid"] for ev in by_ph["X"]}
    assert serving_pids.isdisjoint(dma_pids)


def test_null_tracer_records_and_allocates_nothing():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.events == ()
    # the shared null context: no per-call allocation on the hot path
    assert NULL_TRACER.span("a", "b") is NULL_TRACER.span("c", "d")
    NULL_TRACER.decision("admit", rid=1)
    NULL_TRACER.counter("g", "x", 1)
    assert NULL_TRACER.events == ()
    with pytest.raises(RuntimeError):
        NULL_TRACER.to_chrome()


def test_args_are_json_safe_and_restored():
    t = Tracer()
    t.instant("pages", "deadline", cat="page",
              seq=0, clock=0, page=1, deadline=math.inf, pinned=(1, 2))
    doc = t.to_chrome()
    import json
    doc = json.loads(json.dumps(doc))       # must survive a real round-trip
    (ev,) = [e for e in doc["traceEvents"] if e.get("cat") == "page"]
    assert ev["args"]["deadline"] == "inf"
    assert ev["args"]["pinned"] == [1, 2]
    (pe,) = page_events_from_chrome(doc)
    assert pe.deadline == math.inf and pe.pinned == (1, 2)


# ======================================================================== #
# schema-validator fixtures
# ======================================================================== #

def _ev(**kw):
    base = {"ph": "i", "name": "x", "pid": 1, "tid": 1, "ts": 0.0, "s": "t"}
    base.update(kw)
    return base


def test_validator_catches_unbalanced_spans():
    doc = {"traceEvents": [_ev(ph="B"), _ev(ph="E"), _ev(ph="E")]}
    assert any("no open 'B'" in e for e in validate_chrome_trace(doc))
    doc = {"traceEvents": [_ev(ph="B")]}
    assert any("never closed" in e for e in validate_chrome_trace(doc))


def test_validator_catches_unknown_phase_and_bad_ts():
    assert any("unknown phase" in e for e in validate_chrome_trace(
        {"traceEvents": [_ev(ph="Q")]}))
    assert any("non-finite ts" in e for e in validate_chrome_trace(
        {"traceEvents": [_ev(ts=math.inf)]}))


def test_validator_catches_async_and_complete_misuse():
    assert any("async 'e' before 'b'" in e for e in validate_chrome_trace(
        {"traceEvents": [_ev(ph="e", cat="request", id=7)]}))
    assert any("missing 'id'" in e for e in validate_chrome_trace(
        {"traceEvents": [_ev(ph="b", cat="request")]}))
    assert any("dur >= 0" in e for e in validate_chrome_trace(
        {"traceEvents": [_ev(ph="X", dur=-1.0)]}))


def test_validator_catches_empty_counter():
    assert any("no numeric args" in e for e in validate_chrome_trace(
        {"traceEvents": [_ev(ph="C", args={"note": "text"})]}))


# ======================================================================== #
# engine round-trip (reduced model; one traced + one untraced run, cached)
# ======================================================================== #

_MODEL = {}


def _model():
    if not _MODEL:
        cfg = get_config("qwen3-1.7b").reduced()
        m = build_model(dataclasses.replace(cfg, paged_kv=True))
        _MODEL["cfg"] = cfg
        _MODEL["params"] = m.init(jax.random.PRNGKey(0))
    return _MODEL["cfg"], _MODEL["params"]


def _mixed_run(tracer=None, shadow=False, hook=None):
    """Two long low-priority decoders + one short high-priority request
    under the priority policy: forces a preemption, so the run exercises
    swap-out (evictions), resume (restores/faults), and the full request/
    slot async-span lifecycle."""
    cfg, params = _model()
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8,
        prefill_buckets=(8, 16, 32), policy="priority",
        shadow_check=shadow), metrics_hook=hook, tracer=tracer)
    rng = np.random.default_rng(7)
    for i in range(2):
        eng.submit(Request(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, size=12).tolist(),
            max_new_tokens=8, priority=0))
    for _ in range(2):
        eng.step()
    eng.submit(Request(
        rid=100, prompt=rng.integers(1, cfg.vocab_size, size=6).tolist(),
        max_new_tokens=3, priority=1, ttft_deadline=4))
    out = eng.run(max_ticks=64)
    return eng, out


_RUNS = {}


def _traced():
    if "traced" not in _RUNS:
        tracer = Tracer()
        eng, out = _mixed_run(tracer=tracer, shadow=True)
        _RUNS["traced"] = (eng, out, tracer.to_chrome())
    return _RUNS["traced"]


def _untraced():
    if "untraced" not in _RUNS:
        _RUNS["untraced"] = _mixed_run()
    return _RUNS["untraced"]


def test_traced_run_exports_valid_trace():
    eng, _, doc = _traced()
    assert validate_chrome_trace(doc) == []
    assert eng.metrics.preemptions >= 1, "workload must force a preemption"
    names = {ev.get("name") for ev in doc["traceEvents"]}
    assert {"tick", "admit", "decode"} <= names          # engine spans
    assert "preempt" in names                            # reasoned decision
    # counters never carry tick in args (each key renders as a series)
    for ev in doc["traceEvents"]:
        if ev["ph"] == "C":
            assert "tick" not in (ev.get("args") or {})


def test_page_bridge_reconstructs_pool_trace_exactly():
    eng, _, doc = _traced()
    rebuilt = page_events_from_chrome(doc)
    assert rebuilt == list(eng.pool.trace.events)
    assert any(e.kind.value == "evict" for e in rebuilt)    # the preemption
    assert any(e.kind.value == "restore" for e in rebuilt)  # the resume


def test_reconstructed_trace_replays_clean_through_sanitizer():
    _, _, doc = _traced()
    violations = LifecycleChecker().feed(page_events_from_chrome(doc))
    assert violations == [], [v for v in violations]


def test_decision_stream_carries_reasons():
    _, _, doc = _traced()
    stream = trace_diff.decision_stream(doc)
    labels = [label for label, _, _ in stream]
    assert "decision:admit" in labels
    assert "decision:preempt" in labels and "decision:resume" in labels
    (preempt,) = [a for label, a, _ in stream if label == "decision:preempt"]
    assert preempt["reason"] == "priority"


def test_tracing_off_records_nothing_and_stays_deterministic():
    eng_t, out_t, _ = _traced()
    eng_u, out_u = _untraced()
    assert eng_u.tracer is NULL_TRACER and eng_u.tracer.events == ()
    assert out_u == out_t                       # token streams identical
    volatile = ("tokens_per_sec", "wall_time")
    snap_t = {k: v for k, v in eng_t.snapshot().items() if k not in volatile}
    snap_u = {k: v for k, v in eng_u.snapshot().items() if k not in volatile}
    assert snap_u == snap_t


def test_crashing_metrics_hook_warns_once_and_is_disabled():
    calls = []

    def hook(snap):
        calls.append(snap["tick"])
        raise ValueError("observer bug")

    with pytest.warns(RuntimeWarning, match="disabling the hook"):
        eng, out = _mixed_run(hook=hook)
    assert len(calls) == 1, "hook must be disabled after the first raise"
    assert eng.metrics_hook is None
    assert out == _untraced()[1], "a hook crash must not perturb the run"


# ======================================================================== #
# cache economics + metrics registry
# ======================================================================== #

def test_cache_economics_arithmetic():
    pm = PoolMetrics(page_faults=3, evictions=2, bytes_hot_written=1000,
                     planned_preloads=3, useful_preloads=2,
                     wasted_preloads=1)
    econ = cache_economics(page_bytes=100, tokens_emitted=10,
                           pool_metrics=pm)
    hot, cold = econ["tiers"]["hot"], econ["tiers"]["cold"]
    assert hot["bytes_in"] == 3 * 100 + 1000    # restores + scatter fills
    assert hot["bytes_out"] == 2 * 100
    assert hot["bytes_per_token"] == (300 + 1000 + 200) / 10
    assert cold == {"bytes_in": 200, "bytes_out": 300, "bytes_moved": 500,
                    "bytes_per_token": 50.0}
    pf = econ["prefetch"]
    assert pf["accuracy"] == pytest.approx(2 / 3)
    assert pf["coverage"] == 1.0                # all restores were planned


def test_registry_exporters():
    reg = MetricsRegistry()
    reg.set("pul_x", 1.5, help="an x", tier="hot")
    reg.set("pul_x", 2.5, tier="cold")
    reg.inc("pul_y", 2)
    reg.inc("pul_y", 3)
    assert reg.get("pul_x", tier="cold") == 2.5
    assert reg.get("pul_y") == 5.0
    prom = reg.to_prometheus()
    assert "# HELP pul_x an x" in prom
    assert '# TYPE pul_x gauge' in prom
    assert 'pul_x{tier="hot"} 1.5' in prom
    assert prom.endswith("\n")
    js = reg.to_json()
    assert js["pul_y"] == [{"labels": {}, "value": 5.0}]


def test_engine_metrics_registry_has_economics():
    eng, _, _ = _traced()
    reg = eng.metrics_registry()
    econ = eng.economics()
    assert (reg.get("pul_cache_bytes_per_token", tier="hot",
                    policy="priority")
            == econ["tiers"]["hot"]["bytes_per_token"])
    assert reg.get("pul_engine_tokens_emitted", policy="priority") \
        == eng.metrics.tokens_emitted
    # every policy report must expose prefetch quality
    for k in ("accuracy", "timeliness", "coverage"):
        assert reg.get(f"pul_prefetch_{k}", policy="priority") is not None
    economics_into_registry(reg, econ, run="again")
    assert reg.get("pul_tokens_emitted", run="again") is not None


# ======================================================================== #
# DMA FIFO occupancy: executed trace vs symbolic schedule
# ======================================================================== #

_WL = KVPageWorkload(page_bytes=16 * 128 * 2,
                     flops_per_page=4.0 * 16 * 128 * 4,
                     pages_per_step=4, steps=16)


def _traced_dma(distance, fifo_depth=64):
    tracer = Tracer()
    eng = DMAEngine(TIERS["remote_hbm"], PES["tpu_v5e_vpu"],
                    fifo_depth=fifo_depth, tracer=tracer)
    run_kv_page_workload(eng, _WL, distance=distance)
    cfg = PULConfig(distance=min(distance, fifo_depth),
                    fifo_depth=fifo_depth, unload_distance=1)
    return eng, cfg, tracer


def test_fifo_diff_empty_on_clean_run():
    eng, cfg, tracer = _traced_dma(distance=8)
    pre, _ = eng.last_channels
    assert diff_fifo_occupancy(cfg, n_blocks=_WL.n_pages, channel=pre,
                               engine_fifo_depth=eng.fifo_depth) == []
    doc = tracer.to_chrome()
    assert validate_chrome_trace(doc) == []
    # the high-water instant rides along in the export
    assert any(ev.get("name") == "fifo-high-water"
               for ev in doc["traceEvents"])


def test_fifo_diff_empty_under_back_pressure():
    eng, cfg, _ = _traced_dma(distance=8, fifo_depth=4)
    pre, _ = eng.last_channels
    assert pre.stalls, "shallow FIFO must produce back-pressure stalls"
    assert diff_fifo_occupancy(cfg, n_blocks=_WL.n_pages, channel=pre,
                               engine_fifo_depth=eng.fifo_depth) == []


def test_fifo_diff_catches_corrupted_occupancy():
    eng, cfg, _ = _traced_dma(distance=8)
    pre, _ = eng.last_channels
    t, _occ = pre.occupancy_log[0]
    pre.occupancy_log[0] = (t, 99)
    diff = diff_fifo_occupancy(cfg, n_blocks=_WL.n_pages, channel=pre,
                               engine_fifo_depth=eng.fifo_depth)
    assert any("exceeds the symbolic in-flight window" in d for d in diff)


# ======================================================================== #
# trace_diff tool semantics
# ======================================================================== #

def _decision_doc(policy, n=3):
    t = Tracer()
    for i in range(n):
        t.set_tick(i)                       # volatile: ignored by the diff
        t.decision("admit", rid=i, policy=policy, reason="capacity")
    return t.to_chrome()


def test_trace_diff_ignores_volatile_keys():
    a = trace_diff.decision_stream(_decision_doc("fcfs"))
    b = trace_diff.decision_stream(_decision_doc("fcfs"))
    assert trace_diff.diff_decisions(a, b) is None


def test_trace_diff_reports_first_divergence_with_reason():
    a = trace_diff.decision_stream(_decision_doc("fcfs"))
    b = trace_diff.decision_stream(_decision_doc("slo-edf"))
    idx, why = trace_diff.diff_decisions(a, b)
    assert idx == 0
    assert "policy" in why and "'fcfs'" in why and "reason" in why


def test_trace_diff_reports_length_mismatch():
    a = trace_diff.decision_stream(_decision_doc("fcfs", n=2))
    b = trace_diff.decision_stream(_decision_doc("fcfs", n=4))
    idx, why = trace_diff.diff_decisions(a, b)
    assert idx == 2 and "continues alone" in why

"""Fused single-sweep paged decode: parity + zero-copy properties.

The KVStoreLayout redesign's satellite contract:

  * the fused sweep (``sweep_decode=True``, the default) produces BITWISE
    identical token streams to the per-layer kernel path
    (``sweep_decode=False``) across the zoo subset AND deepseek MLA,
    including a preempt/resume landing mid-chunked-prefill;
  * ``layer_view`` never copies: its jaxpr contains no data-movement
    primitive, and a jitted plane commit with donated planes aliases the
    input buffers in place (CPU buffer donation — the same mechanism the
    engine's ``_sweep_decode`` uses via ``donate_argnums``);
  * the deprecated v1 surface (``page_views`` / ``pack_new_rows``) warns
    ``PendingDeprecationWarning`` and no in-repo caller reaches it.
"""
import dataclasses
import functools
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    PackedKVLayout,
    PagedEngineConfig,
    PagedServingEngine,
    Request,
)

pytestmark = pytest.mark.paged

# dense archs (MoE capacity dispatch is batch-composition-sensitive, but
# both paths below run IDENTICAL schedules, so deepseek still compares
# bitwise in its own test)
ZOO_SUBSET = ("qwen3-1.7b", "gemma2-27b", "qwen2.5-32b")

_MODELS = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = get_config(arch).reduced()
        m = build_model(dataclasses.replace(cfg, paged_kv=True))
        params = m.init(jax.random.PRNGKey(0))
        _MODELS[arch] = (cfg, m, params)
    return _MODELS[arch]


def _engine(arch, sweep, **cfg_kw):
    cfg, _, params = _model(arch)
    kw = dict(batch_slots=2, max_seq=64, page_tokens=8,
              prefill_buckets=(8, 16, 32), use_paged_kernel=True,
              sweep_decode=sweep)
    kw.update(cfg_kw)
    return PagedServingEngine(cfg, params, PagedEngineConfig(**kw))


def _run_both(arch, drive, **cfg_kw):
    """Run the same driver on a fused-sweep and a per-layer engine; return
    both engines and their token streams."""
    outs, engines = [], []
    for sweep in (True, False):
        eng = _engine(arch, sweep, **cfg_kw)
        outs.append(drive(eng))
        engines.append(eng)
    return engines, outs


# ======================================================================== #
# sweep vs per-layer path: bitwise stream parity
# ======================================================================== #

@pytest.mark.parametrize("arch", ZOO_SUBSET)
def test_sweep_matches_per_layer_path(arch):
    """Mixed prompt lengths with a mid-stream slot refill: the single-sweep
    fused decode and the per-layer launch loop are the same math over the
    same planes, so the streams must match token for token."""
    cfg, _, _ = _model(arch)

    def drive(eng):
        rng = np.random.default_rng(7)
        for i, n in enumerate((3, 17, 8)):
            eng.submit(Request(
                rid=i, prompt=rng.integers(1, cfg.vocab_size, size=n).tolist(),
                max_new_tokens=6))
        return eng.run()

    (sweep_eng, ref_eng), (got_sweep, got_ref) = _run_both(arch, drive)
    assert got_sweep == got_ref
    assert sweep_eng.metrics.prefills == ref_eng.metrics.prefills
    # and the sweep really took the fused path: the eager scatter is off,
    # yet the pool accounted the same committed row bytes
    assert sweep_eng.pool.metrics.bytes_hot_written \
        == ref_eng.pool.metrics.bytes_hot_written > 0


def test_sweep_matches_per_layer_path_mla():
    """deepseek MLA: absorbed decode over compressed-KV planes, fused sweep
    vs per-layer — multi-page, sub-page, and partial-tail lengths."""
    cfg, _, _ = _model("deepseek-v2-236b")
    for seed, plen in ((2, 19), (3, 5)):
        p = np.random.default_rng(seed).integers(
            1, cfg.vocab_size, size=plen).tolist()

        def drive(eng):
            eng.submit(Request(rid=0, prompt=list(p), max_new_tokens=8))
            return eng.run()

        _, (got_sweep, got_ref) = _run_both("deepseek-v2-236b", drive)
        assert got_sweep == got_ref, f"len {plen}"


def test_sweep_parity_mid_chunk_preempt_resume():
    """A high-priority arrival preempts a slot whose chunked prefill is
    still in flight; the victim later resumes from the cold tier and
    finishes its ladder. Both decode paths must walk the identical
    schedule and emit identical streams."""
    cfg, _, _ = _model("qwen3-1.7b")
    long = np.random.default_rng(10).integers(
        1, cfg.vocab_size, size=20).tolist()
    hi = np.random.default_rng(11).integers(
        1, cfg.vocab_size, size=4).tolist()

    def drive(eng):
        eng.submit(Request(rid=0, prompt=list(long), max_new_tokens=6,
                           priority=0))
        eng.step()                          # one 8-token chunk banked
        assert 0 in eng._chunk and eng._chunk[0]["filled"] == 8
        eng.submit(Request(rid=1, prompt=list(hi), max_new_tokens=3,
                           priority=2))
        return eng.run()

    engines, (got_sweep, got_ref) = _run_both(
        "qwen3-1.7b", drive, batch_slots=1, policy="priority",
        prefill_chunk_tokens=8)
    assert got_sweep == got_ref
    for eng in engines:
        assert eng.metrics.preemptions == 1 and eng.metrics.readmissions == 1
        assert eng.pool.metrics.page_faults >= 1    # resumed through cold


# ======================================================================== #
# zero-copy properties of the v2 layout
# ======================================================================== #

# jaxpr primitives that move or rearrange data: none may appear in a
# layer_view trace — a true view is static leading-axis indexing only
_COPYING_PRIMS = {"gather", "concatenate", "transpose", "dynamic_slice",
                  "scatter", "reshape", "copy", "convert_element_type"}


@settings(max_examples=12, deadline=None)
@given(arch=st.sampled_from(ZOO_SUBSET + ("deepseek-v2-236b",)),
       layer=st.integers(0, 63), n_frames=st.integers(2, 9))
def test_layer_view_never_copies(arch, layer, n_frames):
    """Property: for any arch, layer, and frame count, layer_view's jaxpr
    is pure static slicing — no gather, concat, transpose, or reshape. This
    is the structural guarantee that the per-layer kernel path launches on
    the pool's own buffers rather than per-step repacks."""
    cfg = get_config(arch).reduced()
    layout = PackedKVLayout(cfg, 1, 8)
    layers = max(e.layers for e in layout.entries)
    g = layer % layers
    planes = layout.init_planes(n_frames, 8, jnp.bfloat16)
    jaxpr = jax.make_jaxpr(lambda p: layout.layer_view(p, g))(planes)
    prims = {str(eqn.primitive) for eqn in jaxpr.jaxpr.eqns}
    assert not prims & _COPYING_PRIMS, prims


def test_donated_plane_commit_aliases_in_place():
    """The engine's sweep entry point donates the planes
    (``donate_argnums``): a jitted commit must reuse the input buffers —
    the donated arrays die and the outputs sit at the same addresses. This
    is the runtime half of the zero-copy claim (and what lint rule PUL107
    enforces statically)."""
    cfg = get_config("qwen3-1.7b").reduced()
    layout = PackedKVLayout(cfg, 1, 8)
    planes = layout.init_planes(4, 8, jnp.bfloat16)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def commit(pl):
        return {k: v.at[(0,) * v.ndim].set(1.0) for k, v in pl.items()}

    ptrs = {k: v.unsafe_buffer_pointer() for k, v in planes.items()}
    out = commit(planes)
    assert all(v.is_deleted() for v in planes.values())
    assert {k: v.unsafe_buffer_pointer() for k, v in out.items()} == ptrs


# ======================================================================== #
# deprecated v1 surface
# ======================================================================== #

def test_deprecated_v1_api_warns():
    cfg = get_config("qwen3-1.7b").reduced()
    layout = PackedKVLayout(cfg, 1, 16)
    store = jnp.zeros((3, 16, layout.features), jnp.bfloat16)
    # the shims warn before touching the tree, so a None tree suffices
    with pytest.warns(PendingDeprecationWarning, match="page_views"):
        try:
            layout.page_views(None, store)
        except (KeyError, TypeError, AttributeError):
            pass
    with pytest.warns(PendingDeprecationWarning, match="pack_new_rows"):
        try:
            layout.pack_new_rows(None)
        except (KeyError, TypeError, AttributeError):
            pass


def test_no_in_repo_caller_uses_deprecated_v1_api():
    """Static closure of the migration: outside kv_pages.py itself (the
    definitions + their deprecation tests' fixtures), nothing in the repo
    calls .page_views( or .pack_new_rows(."""
    root = Path(__file__).resolve().parent.parent
    offenders = []
    for sub in ("src", "benchmarks", "examples", "tools"):
        for f in sorted((root / sub).rglob("*.py")):
            if f.name == "kv_pages.py":
                continue
            text = f.read_text()
            for needle in (".page_views(", ".pack_new_rows("):
                if needle in text:
                    offenders.append(f"{f.relative_to(root)}: {needle}")
    assert offenders == [], offenders

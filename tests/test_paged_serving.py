"""Differential + invariant tests for the paged, PUL-tiered serving engine.

The core contract: the paged engine's greedy token streams are IDENTICAL to
a dense-cache reference decode (same model fns, monolithic per-slot cache),
for mixed prompt lengths, mid-stream slot refills, prefix-shared pages, and
preempt/evict/restore round-trips through the cold tier.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    PagedEngineConfig,
    PagedServingEngine,
    Request,
)

pytestmark = pytest.mark.paged

# dense archs only: MoE capacity dispatch mixes tokens across the batch, so
# MoE outputs are not bitwise batch-composition-invariant (documented trade)
ZOO_SUBSET = ("qwen3-1.7b", "gemma2-27b", "qwen2.5-32b")

_MODELS = {}


def _model(arch):
    """Reduced paged-mode model + params, cached across tests."""
    if arch not in _MODELS:
        cfg = get_config(arch).reduced()
        m = build_model(dataclasses.replace(cfg, paged_kv=True))
        params = m.init(jax.random.PRNGKey(0))
        _MODELS[arch] = (cfg, m, params)
    return _MODELS[arch]


def _set_idx(tree, vec):
    flat, td = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = tuple(getattr(p, "key", str(p)) for p in path)
        if keys[-1] == "idx":
            leaf = jnp.broadcast_to(jnp.asarray(vec, jnp.int32), leaf.shape)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(td, out)


def _pick_bucket(buckets, n, max_seq=64):
    """Mirror of AdmissionScheduler.pick_bucket: prompts longer than the
    largest configured bucket prefill at max_seq (the implicit top bucket)
    instead of being truncated."""
    for b in buckets:
        if n <= b:
            return b
    return max(max_seq, buckets[-1])


def dense_reference(model, params, prompt, max_new, bucket, *, B, max_seq):
    """Per-request greedy decode over a monolithic dense cache — the oracle.

    Uses the same compiled shapes as the engine (batch B, right-padded
    bucket prefill, per-slot idx), so row 0's math is bitwise identical and
    token streams must match exactly."""
    prompt = prompt[-bucket:]
    toks = np.zeros((B, bucket), np.int32)
    toks[0, :len(prompt)] = prompt
    lengths = np.ones((B,), np.int32)
    lengths[0] = len(prompt)
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, max_seq=max_seq))(
        params, {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lengths)})
    out = [int(np.argmax(np.asarray(logits)[0]))]
    pos = np.zeros((B,), np.int32)
    pos[0] = len(prompt)
    caches = _set_idx(caches, pos)
    dec = jax.jit(model.decode_step)
    for _ in range(max_new - 1):
        step = np.zeros((B, 1), np.int32)
        step[0, 0] = out[-1]
        logits, caches = dec(params, {"tokens": jnp.asarray(step),
                                      "pos0": jnp.asarray(pos)}, caches)
        pos = pos + 1
        caches = _set_idx(caches, pos)
        out.append(int(np.argmax(np.asarray(logits)[0])))
    return out


# --------------------------------------------------------------------------
# differential: paged engine == dense reference
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ZOO_SUBSET)
def test_paged_engine_matches_dense_reference(arch):
    """Mixed prompt lengths, more requests than slots (mid-stream refills):
    greedy token streams match the dense-cache reference exactly."""
    cfg, model, params = _model(arch)
    buckets = (8, 16, 32)
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=buckets))
    rng = np.random.default_rng(42)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(n)).tolist()
               for n in (3, 17, 8, 29, 11)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    got = eng.run()
    assert eng.metrics.prefills >= 3     # slots refilled mid-stream
    for i, p in enumerate(prompts):
        want = dense_reference(model, params, p, 6,
                               _pick_bucket(buckets, len(p)),
                               B=2, max_seq=64)
        assert got[i] == want, f"{arch} req {i}: {got[i]} != {want}"


def test_paged_kv_decode_parity_full_forward():
    """paged_kv decode (dense local caches + explicit window mask) agrees
    with the full forward pass — the ground truth, not just the ring path."""
    cfg, model, params = _model("gemma2-27b")
    assert cfg.sliding_window == 16
    B, S = 1, 40                                    # window wraps (40 > 16)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                                cfg.vocab_size, jnp.int32)
    logits_full = model.prefill(params, {"tokens": tokens})[0]
    _, caches = model.prefill(params, {"tokens": tokens[:, :S - 1]},
                              max_seq=S)
    caches = _set_idx(caches, np.full((B,), S - 1, np.int32))
    logits_dec, _ = model.decode_step(
        params, {"tokens": tokens[:, S - 1:],
                 "pos0": jnp.full((B,), S - 1, jnp.int32)}, caches)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full), atol=0.05)


# --------------------------------------------------------------------------
# prefix sharing
# --------------------------------------------------------------------------
def test_prefix_sharing_reuses_physical_pages_and_matches():
    cfg, model, params = _model("qwen3-1.7b")
    base = list(range(5, 21))                        # 2 full pages of 8
    p1, p2 = base + [33, 34], base + [77]
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(32,)))
    eng.submit(Request(rid=0, prompt=p1, max_new_tokens=5))
    eng.submit(Request(rid=1, prompt=p2, max_new_tokens=5))
    reqs = {r.rid: r for r in eng.scheduler.queue}
    eng.step()                                       # both admitted together
    s0 = next(i for i, r in enumerate(eng.slot_req) if r and r.rid == 0)
    s1 = next(i for i, r in enumerate(eng.slot_req) if r and r.rid == 1)
    assert eng.slot_pages[s0][:2] == eng.slot_pages[s1][:2]   # same pages
    assert eng.slot_pages[s0][2:] != eng.slot_pages[s1][2:]   # private tails
    eng.run()
    assert eng.pool.metrics.shared_hits == 2
    for rid, p in ((0, p1), (1, p2)):
        want = dense_reference(model, params, p, 5, 32, B=2, max_seq=64)
        assert reqs[rid].out_tokens == want

    # sharing off: same outputs, no shared pages
    eng2 = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(32,),
        share_prefix_pages=False))
    eng2.submit(Request(rid=0, prompt=p1, max_new_tokens=5))
    eng2.submit(Request(rid=1, prompt=p2, max_new_tokens=5))
    out2 = eng2.run()
    assert eng2.pool.metrics.shared_hits == 0
    assert out2[0] == reqs[0].out_tokens and out2[1] == reqs[1].out_tokens


# --------------------------------------------------------------------------
# tiering: preempt -> evict -> cold -> restore, bit-identical
# --------------------------------------------------------------------------
def test_preempt_evict_restore_roundtrip_is_exact():
    cfg, model, params = _model("qwen3-1.7b")
    rng = np.random.default_rng(3)
    pA = rng.integers(1, cfg.vocab_size, size=20).tolist()
    pB = rng.integers(1, cfg.vocab_size, size=12).tolist()
    want = dense_reference(model, params, pA, 10, 32, B=2, max_seq=64)

    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(32,)))
    eng.submit(Request(rid=0, prompt=pA, max_new_tokens=10))
    eng.submit(Request(rid=1, prompt=pB, max_new_tokens=10))
    reqs = {r.rid: r for r in eng.scheduler.queue}
    for _ in range(4):
        eng.step()
    slot = next(i for i, r in enumerate(eng.slot_req) if r and r.rid == 0)
    eng.preempt(slot)                   # A's pages spill to the cold tier
    assert eng.pool.metrics.evictions > 0
    assert len(eng.pool.cold) > 0
    for _ in range(3):
        eng.step()                      # B keeps decoding with A swapped out
    eng.resume(slot)
    eng.run()
    assert eng.pool.metrics.page_faults >= eng.pool.metrics.evictions
    assert reqs[0].out_tokens == want   # restore was bit-exact
    assert len(eng.pool.cold) == 0      # everything drained


def test_pool_releases_everything_after_run():
    cfg, model, params = _model("qwen3-1.7b")
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(16,)))
    rng = np.random.default_rng(9)
    for i in range(5):
        eng.submit(Request(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, size=9).tolist(),
            max_new_tokens=4))
    eng.run()
    assert eng.pool.hot_in_use() == 0
    assert not eng.pool.pages            # all refcounts returned to zero
    assert not eng.pool.cold
    assert not eng.pool.prefix_index
    assert len(eng.pool.free_frames) == eng.pool.capacity
    assert eng.pool.metrics.pages_allocated > 0


# --------------------------------------------------------------------------
# scheduling: token budget + queue latency
# --------------------------------------------------------------------------
def test_token_budget_serializes_admission_and_records_latency():
    cfg, model, params = _model("qwen3-1.7b")
    # budget fits ONE request (16 + 6 = 22 <= 24 < 44), so the 4 slots are
    # throttled down to sequential admission
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=4, max_seq=64, page_tokens=8, prefill_buckets=(16,),
        max_active_tokens=24))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab_size, size=10).tolist()
               for _ in range(3)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    got = eng.run()
    lats = eng.scheduler.queue_latencies()
    assert len(lats) == 3
    assert lats[0] == 0 and lats[1] > 0 and lats[2] > lats[1]
    for i, p in enumerate(prompts):
        want = dense_reference(model, params, p, 6, 16, B=4, max_seq=64)
        assert got[i] == want

    with pytest.raises(ValueError):     # oversized requests are rejected
        eng.submit(Request(rid=99, prompt=list(range(1, 12)),
                           max_new_tokens=30))


def test_metrics_hook_sees_page_faults_and_throughput():
    cfg, model, params = _model("qwen3-1.7b")
    snaps = []
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=32, page_tokens=8, prefill_buckets=(16,)),
        metrics_hook=snaps.append)
    eng.submit(Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new_tokens=4))
    eng.run()
    assert snaps
    for key in ("tokens_per_sec", "page_faults", "page_faults_step",
                "shared_page_hits", "mean_queue_latency",
                "preload_distance", "modeled_restore_latency_hidden"):
        assert key in snaps[-1]
    assert snaps[-1]["tokens_emitted"] == 4


def test_preempt_resume_preserves_recurrent_state_hybrid():
    """Hybrid (SSM) archs: a paused slot's recurrent state must not be
    advanced by the dummy tokens it rides through the batched decode with —
    preempt/resume must yield the same stream as an undisturbed run."""
    cfg = get_config("zamba2-7b").reduced()
    model = build_model(dataclasses.replace(cfg, paged_kv=True))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(21)
    pA = rng.integers(1, cfg.vocab_size, size=10).tolist()
    pB = rng.integers(1, cfg.vocab_size, size=7).tolist()

    def serve(preempt: bool):
        eng = PagedServingEngine(cfg, params, PagedEngineConfig(
            batch_slots=2, max_seq=32, page_tokens=8, prefill_buckets=(16,)))
        eng.submit(Request(rid=0, prompt=list(pA), max_new_tokens=8))
        eng.submit(Request(rid=1, prompt=list(pB), max_new_tokens=8))
        for _ in range(3):
            eng.step()
        if preempt:
            slot = next(i for i, r in enumerate(eng.slot_req)
                        if r and r.rid == 0)
            eng.preempt(slot)
            for _ in range(2):
                eng.step()       # B decodes while A's state must stay frozen
            eng.resume(slot)
        return eng.run()

    assert serve(preempt=True)[0] == serve(preempt=False)[0]


def test_sampling_uses_model_distribution():
    """greedy=False draws from softmax(logits): reproducible for a fixed
    seed, seed-dependent, and concentrated on high-probability tokens
    (sanity: a tiny overfit-free model still has non-uniform logits)."""
    cfg, model, params = _model("qwen3-1.7b")
    def serve(seed):
        eng = PagedServingEngine(cfg, params, PagedEngineConfig(
            batch_slots=2, max_seq=32, page_tokens=8, prefill_buckets=(16,),
            greedy=False, sample_seed=seed))
        eng.submit(Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new_tokens=8))
        eng.submit(Request(rid=1, prompt=[2, 7, 1, 8], max_new_tokens=8))
        return eng.run()
    a, b, c = serve(0), serve(0), serve(1)
    assert a == b                        # deterministic per seed
    assert a != c                        # seed actually matters
    assert a[0] != a[1]                  # slots don't share one draw


# --------------------------------------------------------------------------
# kernel-true paged decode (use_paged_kernel=True): attention streams
# straight over page frames — no dense per-slot KV view is ever assembled
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch,sizes", [
    ("qwen3-1.7b", (3, 17, 8)),          # GQA + qk_norm
    ("gemma2-27b", (3, 17, 40)),         # sliding window + softcap
    ("qwen2.5-32b", (3, 17, 8)),         # GQA + qkv bias
])
def test_paged_kernel_decode_matches_dense_reference(arch, sizes):
    """With use_paged_kernel=True, greedy token streams are identical to the
    dense-cache reference: mixed prompt lengths, mid-stream slot refills,
    partial tail pages, and window wrap (gemma2's 40 > window 16)."""
    cfg, model, params = _model(arch)
    buckets = (8, 16, 32)
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=buckets,
        use_paged_kernel=True))
    rng = np.random.default_rng(42)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(n)).tolist()
               for n in sizes]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    got = eng.run()
    assert eng.metrics.prefills >= 2     # slots refilled mid-stream
    for i, p in enumerate(prompts):
        want = dense_reference(model, params, p, 6,
                               _pick_bucket(buckets, len(p)),
                               B=2, max_seq=64)
        assert got[i] == want, f"{arch} req {i}: {got[i]} != {want}"


def test_paged_kernel_mla_matches_dense_reference():
    """MLA (deepseek): absorbed decode straight over compressed-KV pages is
    token-identical to the dense reference. Single-request runs keep the
    batch composition identical (MoE capacity dispatch is composition-
    sensitive); lengths cover multi-page, sub-page, and partial tails."""
    cfg = get_config("deepseek-v2-236b").reduced()
    model = build_model(dataclasses.replace(cfg, paged_kv=True))
    params = model.init(jax.random.PRNGKey(0))
    buckets = (8, 16, 32)
    for seed, plen in ((2, 19), (3, 5), (7, 13)):
        p = np.random.default_rng(seed).integers(
            1, cfg.vocab_size, size=plen).tolist()
        eng = PagedServingEngine(cfg, params, PagedEngineConfig(
            batch_slots=2, max_seq=64, page_tokens=8,
            prefill_buckets=buckets, use_paged_kernel=True))
        eng.submit(Request(rid=0, prompt=list(p), max_new_tokens=10))
        got = eng.run()[0]
        want = dense_reference(model, params, p, 10,
                               _pick_bucket(buckets, plen), B=2, max_seq=64)
        assert got == want, f"len {plen}: {got} != {want}"


@pytest.mark.parametrize("use_kernel", [False, True])
def test_empty_prompt_admission(use_kernel):
    """Empty-prompt requests admit cleanly (no pages at prefill, tail page
    on the first decode step) and match the dense reference, on both the
    assembly and the kernel-true decode paths."""
    cfg, model, params = _model("qwen3-1.7b")
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=32, page_tokens=8, prefill_buckets=(8,),
        use_paged_kernel=use_kernel))
    eng.submit(Request(rid=0, prompt=[], max_new_tokens=5))
    got = eng.run()[0]
    assert len(got) == 5
    want = dense_reference(model, params, [], 5, 8, B=2, max_seq=32)
    assert got == want


# --------------------------------------------------------------------------
# prefix-cache COMPUTE reuse: fully-shared prompts skip prefill entirely
# --------------------------------------------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True])
def test_fully_shared_prompt_skips_prefill_compute(use_kernel):
    """A request whose whole (page-aligned) prompt is already resident as
    shared pages admits with ZERO prefill compute: the prefill counter is
    unchanged, the shared pages are ref'd, and the stream is identical to
    an undisturbed solo run — on both decode paths."""
    cfg, model, params = _model("qwen3-1.7b")
    prompt = list(range(5, 21))                     # 16 tokens = 2 full pages
    ecfg = dict(batch_slots=2, max_seq=64, page_tokens=8,
                prefill_buckets=(16,), use_paged_kernel=use_kernel)
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(**ecfg))
    eng.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=12))
    for _ in range(3):
        eng.step()
    assert eng.metrics.prefills == 1
    eng.submit(Request(rid=1, prompt=list(prompt), max_new_tokens=12))
    eng.step()                                      # admitted from shared pages
    assert eng.metrics.prefills == 1                # ZERO additional compute
    assert eng.metrics.prefill_skips == 1
    assert eng.pool.metrics.shared_hits == 2        # both prompt pages reused
    out = eng.run()

    solo = PagedServingEngine(cfg, params, PagedEngineConfig(**ecfg))
    solo.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=12))
    want = solo.run()[0]
    assert out[0] == want and out[1] == want

    # non-page-aligned prompts never skip (the partial tail needs compute)
    eng2 = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(16,)))
    eng2.submit(Request(rid=0, prompt=list(prompt[:-3]), max_new_tokens=12))
    for _ in range(3):
        eng2.step()
    eng2.submit(Request(rid=1, prompt=list(prompt[:-3]), max_new_tokens=4))
    eng2.run()
    assert eng2.metrics.prefill_skips == 0
    assert eng2.metrics.prefills == 2


def test_recurrent_archs_never_skip_prefill():
    """Hybrid (SSM) archs carry non-pageable state that pages cannot
    rebuild: identical prompts must still prefill."""
    cfg = get_config("zamba2-7b").reduced()
    model = build_model(dataclasses.replace(cfg, paged_kv=True))
    params = model.init(jax.random.PRNGKey(0))
    prompt = list(range(1, 17))                     # page-aligned on purpose
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(16,)))
    eng.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=10))
    for _ in range(3):
        eng.step()
    eng.submit(Request(rid=1, prompt=list(prompt), max_new_tokens=4))
    out = eng.run()
    assert eng.metrics.prefill_skips == 0
    assert eng.metrics.prefills == 2
    assert out[0][:4] == out[1][:4]                 # same prompt, same start


# --------------------------------------------------------------------------
# admission-accounting regressions
# --------------------------------------------------------------------------
def test_token_budget_accounting_matches_scheduler_cost():
    """Regression: the engine's per-tick active-token charge must be the
    scheduler's request_cost (min(prompt, bucket) + max_new), not
    bucket + max_new — otherwise a short prompt in a large bucket inflates
    the budget between submit-time checks and per-tick accounting, blocking
    admissions the scheduler already proved feasible."""
    cfg, model, params = _model("qwen3-1.7b")
    # cost per request = min(3, 16) + 6 = 9; two fit in budget 18. The
    # drifted charge (16 + 6 = 22) would block the second request forever.
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(16,),
        max_active_tokens=18))
    r0 = Request(rid=0, prompt=[3, 1, 4], max_new_tokens=6)
    r1 = Request(rid=1, prompt=[1, 5, 9], max_new_tokens=6)
    eng.submit(r0)
    eng.step()
    assert eng._active_tokens() == 9
    eng.submit(r1)
    eng.step()
    assert r1.admit_tick == 1           # admitted immediately, not serialized
    assert eng._active_tokens() == 18
    eng.run()
    assert len(r0.out_tokens) == 6 and len(r1.out_tokens) == 6


def test_dense_run_returns_preadmitted_requests():
    """Regression: ServingEngine.run() must return requests that were
    already admitted into slots before run() was called (the old queue-only
    snapshot silently dropped their outputs)."""
    cfg, model, params = _model("qwen3-1.7b")
    from repro.serving import EngineConfig, ServingEngine
    eng = ServingEngine(cfg, params, EngineConfig(
        batch_slots=2, max_seq=64, prefill_bucket=16))
    eng.submit(Request(rid=0, prompt=[3, 1, 4], max_new_tokens=4))
    eng.step()                           # rid 0 leaves the queue for a slot
    eng.submit(Request(rid=1, prompt=[2, 7], max_new_tokens=4))
    done = eng.run()
    assert set(done) == {0, 1}
    assert len(done[0]) == 4 and len(done[1]) == 4


def test_pool_alloc_preserves_step_working_set():
    """Regression: alloc() must not evict pages the current step still
    needs (stale LRU order made the working set the victim, forcing a
    same-step fault/restore round-trip that polluted the latency-hidden
    metric)."""
    from repro.serving.kv_pages import KVPagePool, PageConfig
    pool = KVPagePool(PageConfig(page_tokens=8, hot_frames=5), features=4)
    assert pool.capacity == 3
    p1, p2, p3 = pool.alloc(), pool.alloc(), pool.alloc()
    pool.ensure_hot([p2, p3])            # p1 becomes the strict LRU entry
    pool.alloc(needed=(p1,))             # full pool: someone must spill...
    assert pool.pages[p1].frame is not None   # ...but never the working set
    assert pool.metrics.evictions == 1
    assert pool.metrics.page_faults == 0      # no same-step churn


def test_write_rows_validates_before_scatter():
    """Regression: the zero-frame invariant is checked BEFORE the scatter —
    a bad frame vector must leave the reserved all-zeros frame untouched."""
    from repro.serving.kv_pages import KVPagePool, PageConfig, ZERO_FRAME
    import jax.numpy as jnp
    pool = KVPagePool(PageConfig(page_tokens=8, hot_frames=4), features=4)
    with pytest.raises(AssertionError):
        pool.write_rows(np.asarray([ZERO_FRAME], np.int32),
                        np.asarray([0], np.int32),
                        jnp.ones((1, 4), jnp.float32))
    assert not np.asarray(pool.store[ZERO_FRAME]).any()   # still all-zeros


def test_sampling_differential_across_engines():
    """greedy=False with one shared sample_seed: the dense and paged
    engines draw identical streams (prompt length == bucket keeps the two
    prefill paddings — left vs right — bitwise equivalent)."""
    cfg, model, params = _model("qwen3-1.7b")
    from repro.serving import EngineConfig, ServingEngine
    prompt = list(range(3, 19))                    # 16 tokens == the bucket
    outs = []
    for seed in (0, 7):
        dense = ServingEngine(cfg, params, EngineConfig(
            batch_slots=2, max_seq=64, prefill_bucket=16, greedy=False,
            sample_seed=seed))
        paged = PagedServingEngine(cfg, params, PagedEngineConfig(
            batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(16,),
            greedy=False, sample_seed=seed))
        for eng in (dense, paged):
            eng.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=8))
        a, b = dense.run()[0], paged.run()[0]
        assert a == b, f"seed {seed}: {a} != {b}"
        outs.append(a)
    assert outs[0] != outs[1]                      # seed actually matters


# --------------------------------------------------------------------------
# Pallas page-gather assembly path
# --------------------------------------------------------------------------
def test_pallas_page_gather_assembly_matches_default():
    cfg, model, params = _model("qwen3-1.7b")
    prompt = list(range(3, 15))
    outs = []
    for use_pallas in (False, True):
        eng = PagedServingEngine(cfg, params, PagedEngineConfig(
            batch_slots=2, max_seq=32, page_tokens=8, prefill_buckets=(16,),
            use_pallas_gather=use_pallas))
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        outs.append(eng.run()[0])
    assert outs[0] == outs[1]

"""Differential + invariant tests for the paged, PUL-tiered serving engine.

The core contract: the paged engine's greedy token streams are IDENTICAL to
a dense-cache reference decode (same model fns, monolithic per-slot cache),
for mixed prompt lengths, mid-stream slot refills, prefix-shared pages, and
preempt/evict/restore round-trips through the cold tier.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    PagedEngineConfig,
    PagedServingEngine,
    Request,
)

pytestmark = pytest.mark.paged

# dense archs only: MoE capacity dispatch mixes tokens across the batch, so
# MoE outputs are not bitwise batch-composition-invariant (documented trade)
ZOO_SUBSET = ("qwen3-1.7b", "gemma2-27b", "qwen2.5-32b")

_MODELS = {}


def _model(arch):
    """Reduced paged-mode model + params, cached across tests."""
    if arch not in _MODELS:
        cfg = get_config(arch).reduced()
        m = build_model(dataclasses.replace(cfg, paged_kv=True))
        params = m.init(jax.random.PRNGKey(0))
        _MODELS[arch] = (cfg, m, params)
    return _MODELS[arch]


def _set_idx(tree, vec):
    flat, td = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = tuple(getattr(p, "key", str(p)) for p in path)
        if keys[-1] == "idx":
            leaf = jnp.broadcast_to(jnp.asarray(vec, jnp.int32), leaf.shape)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(td, out)


def _pick_bucket(buckets, n):
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def dense_reference(model, params, prompt, max_new, bucket, *, B, max_seq):
    """Per-request greedy decode over a monolithic dense cache — the oracle.

    Uses the same compiled shapes as the engine (batch B, right-padded
    bucket prefill, per-slot idx), so row 0's math is bitwise identical and
    token streams must match exactly."""
    prompt = prompt[-bucket:]
    toks = np.zeros((B, bucket), np.int32)
    toks[0, :len(prompt)] = prompt
    lengths = np.ones((B,), np.int32)
    lengths[0] = len(prompt)
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, max_seq=max_seq))(
        params, {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lengths)})
    out = [int(np.argmax(np.asarray(logits)[0]))]
    pos = np.zeros((B,), np.int32)
    pos[0] = len(prompt)
    caches = _set_idx(caches, pos)
    dec = jax.jit(model.decode_step)
    for _ in range(max_new - 1):
        step = np.zeros((B, 1), np.int32)
        step[0, 0] = out[-1]
        logits, caches = dec(params, {"tokens": jnp.asarray(step),
                                      "pos0": jnp.asarray(pos)}, caches)
        pos = pos + 1
        caches = _set_idx(caches, pos)
        out.append(int(np.argmax(np.asarray(logits)[0])))
    return out


# --------------------------------------------------------------------------
# differential: paged engine == dense reference
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ZOO_SUBSET)
def test_paged_engine_matches_dense_reference(arch):
    """Mixed prompt lengths, more requests than slots (mid-stream refills):
    greedy token streams match the dense-cache reference exactly."""
    cfg, model, params = _model(arch)
    buckets = (8, 16, 32)
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=buckets))
    rng = np.random.default_rng(42)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(n)).tolist()
               for n in (3, 17, 8, 29, 11)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    got = eng.run()
    assert eng.metrics.prefills >= 3     # slots refilled mid-stream
    for i, p in enumerate(prompts):
        want = dense_reference(model, params, p, 6,
                               _pick_bucket(buckets, len(p)),
                               B=2, max_seq=64)
        assert got[i] == want, f"{arch} req {i}: {got[i]} != {want}"


def test_paged_kv_decode_parity_full_forward():
    """paged_kv decode (dense local caches + explicit window mask) agrees
    with the full forward pass — the ground truth, not just the ring path."""
    cfg, model, params = _model("gemma2-27b")
    assert cfg.sliding_window == 16
    B, S = 1, 40                                    # window wraps (40 > 16)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                                cfg.vocab_size, jnp.int32)
    logits_full = model.prefill(params, {"tokens": tokens})[0]
    _, caches = model.prefill(params, {"tokens": tokens[:, :S - 1]},
                              max_seq=S)
    caches = _set_idx(caches, np.full((B,), S - 1, np.int32))
    logits_dec, _ = model.decode_step(
        params, {"tokens": tokens[:, S - 1:],
                 "pos0": jnp.full((B,), S - 1, jnp.int32)}, caches)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full), atol=0.05)


# --------------------------------------------------------------------------
# prefix sharing
# --------------------------------------------------------------------------
def test_prefix_sharing_reuses_physical_pages_and_matches():
    cfg, model, params = _model("qwen3-1.7b")
    base = list(range(5, 21))                        # 2 full pages of 8
    p1, p2 = base + [33, 34], base + [77]
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(32,)))
    eng.submit(Request(rid=0, prompt=p1, max_new_tokens=5))
    eng.submit(Request(rid=1, prompt=p2, max_new_tokens=5))
    reqs = {r.rid: r for r in eng.scheduler.queue}
    eng.step()                                       # both admitted together
    s0 = next(i for i, r in enumerate(eng.slot_req) if r and r.rid == 0)
    s1 = next(i for i, r in enumerate(eng.slot_req) if r and r.rid == 1)
    assert eng.slot_pages[s0][:2] == eng.slot_pages[s1][:2]   # same pages
    assert eng.slot_pages[s0][2:] != eng.slot_pages[s1][2:]   # private tails
    eng.run()
    assert eng.pool.metrics.shared_hits == 2
    for rid, p in ((0, p1), (1, p2)):
        want = dense_reference(model, params, p, 5, 32, B=2, max_seq=64)
        assert reqs[rid].out_tokens == want

    # sharing off: same outputs, no shared pages
    eng2 = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(32,),
        share_prefix_pages=False))
    eng2.submit(Request(rid=0, prompt=p1, max_new_tokens=5))
    eng2.submit(Request(rid=1, prompt=p2, max_new_tokens=5))
    out2 = eng2.run()
    assert eng2.pool.metrics.shared_hits == 0
    assert out2[0] == reqs[0].out_tokens and out2[1] == reqs[1].out_tokens


# --------------------------------------------------------------------------
# tiering: preempt -> evict -> cold -> restore, bit-identical
# --------------------------------------------------------------------------
def test_preempt_evict_restore_roundtrip_is_exact():
    cfg, model, params = _model("qwen3-1.7b")
    rng = np.random.default_rng(3)
    pA = rng.integers(1, cfg.vocab_size, size=20).tolist()
    pB = rng.integers(1, cfg.vocab_size, size=12).tolist()
    want = dense_reference(model, params, pA, 10, 32, B=2, max_seq=64)

    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(32,)))
    eng.submit(Request(rid=0, prompt=pA, max_new_tokens=10))
    eng.submit(Request(rid=1, prompt=pB, max_new_tokens=10))
    reqs = {r.rid: r for r in eng.scheduler.queue}
    for _ in range(4):
        eng.step()
    slot = next(i for i, r in enumerate(eng.slot_req) if r and r.rid == 0)
    eng.preempt(slot)                   # A's pages spill to the cold tier
    assert eng.pool.metrics.evictions > 0
    assert len(eng.pool.cold) > 0
    for _ in range(3):
        eng.step()                      # B keeps decoding with A swapped out
    eng.resume(slot)
    eng.run()
    assert eng.pool.metrics.page_faults >= eng.pool.metrics.evictions
    assert reqs[0].out_tokens == want   # restore was bit-exact
    assert len(eng.pool.cold) == 0      # everything drained


def test_pool_releases_everything_after_run():
    cfg, model, params = _model("qwen3-1.7b")
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=(16,)))
    rng = np.random.default_rng(9)
    for i in range(5):
        eng.submit(Request(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, size=9).tolist(),
            max_new_tokens=4))
    eng.run()
    assert eng.pool.hot_in_use() == 0
    assert not eng.pool.pages            # all refcounts returned to zero
    assert not eng.pool.cold
    assert not eng.pool.prefix_index
    assert len(eng.pool.free_frames) == eng.pool.capacity
    assert eng.pool.metrics.pages_allocated > 0


# --------------------------------------------------------------------------
# scheduling: token budget + queue latency
# --------------------------------------------------------------------------
def test_token_budget_serializes_admission_and_records_latency():
    cfg, model, params = _model("qwen3-1.7b")
    # budget fits ONE request (16 + 6 = 22 <= 24 < 44), so the 4 slots are
    # throttled down to sequential admission
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=4, max_seq=64, page_tokens=8, prefill_buckets=(16,),
        max_active_tokens=24))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab_size, size=10).tolist()
               for _ in range(3)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    got = eng.run()
    lats = eng.scheduler.queue_latencies()
    assert len(lats) == 3
    assert lats[0] == 0 and lats[1] > 0 and lats[2] > lats[1]
    for i, p in enumerate(prompts):
        want = dense_reference(model, params, p, 6, 16, B=4, max_seq=64)
        assert got[i] == want

    with pytest.raises(ValueError):     # oversized requests are rejected
        eng.submit(Request(rid=99, prompt=list(range(1, 12)),
                           max_new_tokens=30))


def test_metrics_hook_sees_page_faults_and_throughput():
    cfg, model, params = _model("qwen3-1.7b")
    snaps = []
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=32, page_tokens=8, prefill_buckets=(16,)),
        metrics_hook=snaps.append)
    eng.submit(Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new_tokens=4))
    eng.run()
    assert snaps
    for key in ("tokens_per_sec", "page_faults", "page_faults_step",
                "shared_page_hits", "mean_queue_latency",
                "preload_distance", "modeled_restore_latency_hidden"):
        assert key in snaps[-1]
    assert snaps[-1]["tokens_emitted"] == 4


def test_preempt_resume_preserves_recurrent_state_hybrid():
    """Hybrid (SSM) archs: a paused slot's recurrent state must not be
    advanced by the dummy tokens it rides through the batched decode with —
    preempt/resume must yield the same stream as an undisturbed run."""
    cfg = get_config("zamba2-7b").reduced()
    model = build_model(dataclasses.replace(cfg, paged_kv=True))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(21)
    pA = rng.integers(1, cfg.vocab_size, size=10).tolist()
    pB = rng.integers(1, cfg.vocab_size, size=7).tolist()

    def serve(preempt: bool):
        eng = PagedServingEngine(cfg, params, PagedEngineConfig(
            batch_slots=2, max_seq=32, page_tokens=8, prefill_buckets=(16,)))
        eng.submit(Request(rid=0, prompt=list(pA), max_new_tokens=8))
        eng.submit(Request(rid=1, prompt=list(pB), max_new_tokens=8))
        for _ in range(3):
            eng.step()
        if preempt:
            slot = next(i for i, r in enumerate(eng.slot_req)
                        if r and r.rid == 0)
            eng.preempt(slot)
            for _ in range(2):
                eng.step()       # B decodes while A's state must stay frozen
            eng.resume(slot)
        return eng.run()

    assert serve(preempt=True)[0] == serve(preempt=False)[0]


def test_sampling_uses_model_distribution():
    """greedy=False draws from softmax(logits): reproducible for a fixed
    seed, seed-dependent, and concentrated on high-probability tokens
    (sanity: a tiny overfit-free model still has non-uniform logits)."""
    cfg, model, params = _model("qwen3-1.7b")
    def serve(seed):
        eng = PagedServingEngine(cfg, params, PagedEngineConfig(
            batch_slots=2, max_seq=32, page_tokens=8, prefill_buckets=(16,),
            greedy=False, sample_seed=seed))
        eng.submit(Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new_tokens=8))
        eng.submit(Request(rid=1, prompt=[2, 7, 1, 8], max_new_tokens=8))
        return eng.run()
    a, b, c = serve(0), serve(0), serve(1)
    assert a == b                        # deterministic per seed
    assert a != c                        # seed actually matters
    assert a[0] != a[1]                  # slots don't share one draw


# --------------------------------------------------------------------------
# Pallas page-gather assembly path
# --------------------------------------------------------------------------
def test_pallas_page_gather_assembly_matches_default():
    cfg, model, params = _model("qwen3-1.7b")
    prompt = list(range(3, 15))
    outs = []
    for use_pallas in (False, True):
        eng = PagedServingEngine(cfg, params, PagedEngineConfig(
            batch_slots=2, max_seq=32, page_tokens=8, prefill_buckets=(16,),
            use_pallas_gather=use_pallas))
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        outs.append(eng.run()[0])
    assert outs[0] == outs[1]

"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import IssueStrategy, PULConfig
from repro.kernels import (
    pul_attention,
    pul_filter,
    pul_gather,
    pul_matmul,
    pul_page_gather,
    pul_paged_decode_attention,
    pul_paged_mla_decode_attention,
    pul_sum,
    ref,
)

pytestmark = pytest.mark.kernels

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# --------------------------------------------------------------------- sum
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("distance,strategy", [
    (1, IssueStrategy.BATCH), (4, IssueStrategy.BATCH),
    (3, IssueStrategy.SEQUENTIAL), (16, IssueStrategy.BATCH)])
@pytest.mark.parametrize("rows_per_req", [1, 4])
def test_pul_sum(dtype, distance, strategy, rows_per_req):
    R, W, n = 32, 128, 18
    data = _rand(KEY, (R * rows_per_req, W), dtype)
    trace = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, R, jnp.int32)
    cfg = PULConfig(distance=distance, strategy=strategy)
    got = pul_sum(data, trace, cfg=cfg, rows_per_req=rows_per_req)
    rows = jnp.concatenate([jnp.arange(rows_per_req) + t * rows_per_req
                            for t in trace])
    want = ref.sum_ref(data, rows)
    np.testing.assert_allclose(got, want, rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


# ------------------------------------------------------------------ gather
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("distance", [1, 2, 8])
def test_pul_gather(dtype, distance):
    R, W, n = 64, 256, 40
    if dtype == jnp.int32:
        table = jax.random.randint(KEY, (R, W), -100, 100, jnp.int32)
    else:
        table = _rand(KEY, (R, W), dtype)
    trace = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, R, jnp.int32)
    got = pul_gather(table, trace, cfg=PULConfig(distance=distance))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.gather_ref(table, trace)))


# ------------------------------------------------------------------ matmul
@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("shape,blocks", [
    ((128, 128, 128), (64, 64, 64)),
    ((128, 256, 384), (64, 128, 128)),
    ((64, 512, 128), (64, 64, 128)),
])
@pytest.mark.parametrize("distance", [1, 3])
def test_pul_matmul(dtype, rtol, shape, blocks, distance):
    M, K, N = shape
    bm, bk, bn = blocks
    a = _rand(KEY, (M, K), dtype)
    b = _rand(jax.random.PRNGKey(3), (K, N), dtype)
    got = pul_matmul(a, b, cfg=PULConfig(distance=distance), bm=bm, bk=bk, bn=bn)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol * 10)


# --------------------------------------------------------------- attention
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("gqa", [1, 4])
@pytest.mark.parametrize("softcap,window", [(None, None), (8.0, None), (None, 24)])
def test_pul_attention(dtype, tol, gqa, softcap, window):
    B, K, T, S, hd = 2, 2, 64, 64, 32
    H = K * gqa
    q = _rand(KEY, (B, H, T, hd), dtype) * 0.3
    k = _rand(jax.random.PRNGKey(4), (B, K, S, hd), dtype) * 0.3
    v = _rand(jax.random.PRNGKey(5), (B, K, S, hd), dtype)
    got = pul_attention(q, k, v, cfg=PULConfig(distance=2), bt=32, bs=16,
                        softcap=softcap, window=window)
    want = ref.attention_ref(q, k, v, softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_pul_attention_uneven_kv_tail():
    """S not a multiple of bs exercises the in-kernel tail mask."""
    B, H, T, S, hd = 1, 2, 32, 48, 16
    q = _rand(KEY, (B, H, T, hd), jnp.float32) * 0.5
    k = _rand(jax.random.PRNGKey(6), (B, H, S, hd), jnp.float32) * 0.5
    v = _rand(jax.random.PRNGKey(7), (B, H, S, hd), jnp.float32)
    got = pul_attention(q, k, v, bt=32, bs=32)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


# ------------------------------------------------------------------ filter
@pytest.mark.parametrize("materialize", [False, True])
@pytest.mark.parametrize("distance", [2, 8])
def test_pul_filter(materialize, distance):
    N, W = 512, 64
    data = _rand(KEY, (N, W), jnp.float32)
    got = pul_filter(data, 0.25, cfg=PULConfig(distance=distance),
                     rows_per_block=128, materialize=materialize)
    if materialize:
        want = ref.filter_materialize_ref(data, 0.25)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        want = ref.filter_ref(data, 0.25)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------- property sweep
@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 40),
    rows=st.integers(8, 64),
    d=st.integers(1, 8),
    seq=st.booleans(),
)
def test_gather_roundtrip_property(n, rows, d, seq):
    """gather(table, trace) == table[trace] for arbitrary traces/knobs."""
    table = jax.random.normal(jax.random.PRNGKey(n), (rows, 128), jnp.float32)
    trace = jax.random.randint(jax.random.PRNGKey(n + 1), (n,), 0, rows, jnp.int32)
    cfg = PULConfig(distance=d, strategy=(IssueStrategy.SEQUENTIAL if seq
                                          else IssueStrategy.BATCH))
    got = pul_gather(table, trace, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(table[trace]))


# --------------------------------------------------------------- paged paths
@pytest.mark.parametrize("distance", [1, 4])
@pytest.mark.parametrize("P", [8, 16])
def test_pul_page_gather(distance, P):
    """Page-table gather == store[page_table] (the serving assembly path)."""
    NP, F = 12, 128
    store = _rand(KEY, (NP, P, F), jnp.float32)
    pt = jax.random.randint(jax.random.PRNGKey(11), (3, 4), 0, NP, jnp.int32)
    got = pul_page_gather(store, pt, cfg=PULConfig(distance=distance))
    want = store[pt].reshape(3, 4 * P, F)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("gqa", [1, 4])
@pytest.mark.parametrize("distance", [1, 3])
def test_pul_paged_decode_attention(gqa, distance):
    """Decode attention straight over scattered pages == dense oracle over
    the assembled contiguous cache (mixed fill levels incl. partial pages)."""
    B, K, P, npg, hd = 2, 2, 8, 4, 16
    H, S, NP = K * gqa, P * npg, 11
    kp = _rand(jax.random.PRNGKey(1), (NP, K, P, hd), jnp.float32) * 0.4
    vp = _rand(jax.random.PRNGKey(2), (NP, K, P, hd), jnp.float32)
    pt = jnp.asarray(np.random.default_rng(0).permutation(NP)[:B * npg]
                     .reshape(B, npg), jnp.int32)
    q = _rand(jax.random.PRNGKey(3), (B, H, hd), jnp.float32) * 0.4
    lengths = jnp.asarray([S, S // 2 + 3], jnp.int32)
    got = pul_paged_decode_attention(q, kp, vp, pt, lengths,
                                     cfg=PULConfig(distance=distance))
    kd = kp[pt].transpose(0, 2, 1, 3, 4).reshape(B, K, S, hd)
    vd = vp[pt].transpose(0, 2, 1, 3, 4).reshape(B, K, S, hd)
    want = ref.decode_attention_ref(q, kd, vd, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("window", [None, 11, 24])
@pytest.mark.parametrize("softcap", [None, 8.0])
def test_pul_paged_decode_attention_window_and_self_merge(window, softcap):
    """Sliding-window masking + current-token (k_new, v_new) merge: the
    kernel over scattered pages == dense oracle over [assembled cache ;
    current token], with the window anchored at the query position."""
    B, K, P, npg, hd, gqa = 2, 2, 8, 4, 16, 2
    H, S, NP = K * gqa, P * npg, 9
    kp = _rand(jax.random.PRNGKey(1), (NP, K, P, hd), jnp.float32) * 0.4
    vp = _rand(jax.random.PRNGKey(2), (NP, K, P, hd), jnp.float32)
    kn = _rand(jax.random.PRNGKey(3), (B, K, hd), jnp.float32) * 0.4
    vn = _rand(jax.random.PRNGKey(4), (B, K, hd), jnp.float32)
    q = _rand(jax.random.PRNGKey(5), (B, H, hd), jnp.float32) * 0.4
    pt = jnp.asarray(np.random.default_rng(0).permutation(NP)[:B * npg]
                     .reshape(B, npg) % NP, jnp.int32)
    lengths = jnp.asarray([S - 2, 13], jnp.int32)
    got = pul_paged_decode_attention(q, kp, vp, pt, lengths,
                                     cfg=PULConfig(distance=2),
                                     softcap=softcap, window=window,
                                     k_new=kn, v_new=vn)
    # oracle: assembled dense cache + current token appended at position len
    kd = kp[pt].transpose(0, 2, 1, 3, 4).reshape(B, K, S, hd)
    vd = vp[pt].transpose(0, 2, 1, 3, 4).reshape(B, K, S, hd)
    kk = jnp.repeat(jnp.concatenate([kd, kn[:, :, None]], 2), gqa, 1)
    vv = jnp.repeat(jnp.concatenate([vd, vn[:, :, None]], 2), gqa, 1)
    logits = jnp.einsum("bhd,bhsd->bhs", q, kk) / (hd ** 0.5)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    jk = jnp.arange(S + 1)[None, None, :]
    L = lengths[:, None, None]
    msk = (jk < L) | (jk == S)                    # cached rows + current token
    if window is not None:
        # query sits at absolute position L; the current token (logical
        # position L, stored at column S) is always inside the window
        msk &= (jk > L - window) | (jk == S)
    logits = jnp.where(msk, logits, -2.0e38)
    p = jax.nn.softmax(logits, axis=-1)
    want = jnp.einsum("bhs,bhsd->bhd", p, vv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("distance", [1, 3])
def test_pul_paged_mla_decode_attention(distance):
    """Absorbed MLA decode over compressed-KV pages == dense oracle (the
    compressed cache doubles as the value stream), mixed fill levels."""
    B, H, kvr, dr, P, npg, NP = 2, 4, 32, 8, 8, 4, 11
    S = P * npg
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    qa = _rand(ks[0], (B, H, kvr), jnp.float32) * 0.4
    qr = _rand(ks[1], (B, H, dr), jnp.float32) * 0.4
    cp = _rand(ks[2], (NP, P, kvr), jnp.float32) * 0.4
    rp = _rand(ks[3], (NP, P, dr), jnp.float32) * 0.4
    cn = _rand(ks[4], (B, kvr), jnp.float32) * 0.4
    rn = _rand(ks[5], (B, dr), jnp.float32) * 0.4
    pt = jnp.asarray(np.random.default_rng(1).permutation(NP)[:B * npg]
                     .reshape(B, npg), jnp.int32)
    lengths = jnp.asarray([S, 11], jnp.int32)
    scale = 1.0 / (kvr + dr) ** 0.5
    got = pul_paged_mla_decode_attention(qa, qr, cp, rp, pt, lengths, cn, rn,
                                         scale=scale,
                                         cfg=PULConfig(distance=distance))
    cd = jnp.concatenate([cp[pt].reshape(B, S, kvr), cn[:, None]], 1)
    rd = jnp.concatenate([rp[pt].reshape(B, S, dr), rn[:, None]], 1)
    logits = (jnp.einsum("bhr,bsr->bhs", qa, cd)
              + jnp.einsum("bhd,bsd->bhs", qr, rd)) * scale
    jk = jnp.arange(S + 1)[None, None, :]
    msk = (jk < lengths[:, None, None]) | (jk == S)
    logits = jnp.where(msk, logits, -2.0e38)
    p = jax.nn.softmax(logits, axis=-1)
    want = jnp.einsum("bhs,bsr->bhr", p, cd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_pul_paged_decode_attention_empty_cache():
    """length 0: only the current token is visible (empty-prompt decode)."""
    B, K, P, npg, hd = 1, 2, 8, 2, 16
    kp = _rand(jax.random.PRNGKey(1), (3, K, P, hd), jnp.float32)
    vp = _rand(jax.random.PRNGKey(2), (3, K, P, hd), jnp.float32)
    kn = _rand(jax.random.PRNGKey(3), (B, K, hd), jnp.float32)
    vn = _rand(jax.random.PRNGKey(4), (B, K, hd), jnp.float32)
    q = _rand(jax.random.PRNGKey(5), (B, K, hd), jnp.float32)
    pt = jnp.zeros((B, npg), jnp.int32)
    got = pul_paged_decode_attention(q, kp, vp, pt, jnp.zeros((B,), jnp.int32),
                                     k_new=kn, v_new=vn)
    # softmax over a single visible position == v_new itself
    np.testing.assert_allclose(np.asarray(got), np.asarray(vn),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- decode attention
@pytest.mark.parametrize("gqa", [1, 4])
@pytest.mark.parametrize("distance", [1, 4])
@pytest.mark.parametrize("softcap", [None, 10.0])
def test_pul_decode_attention(gqa, distance, softcap):
    from repro.kernels import pul_decode_attention
    B, K, S, hd = 2, 2, 96, 32
    H = K * gqa
    q = _rand(KEY, (B, H, hd), jnp.float32) * 0.4
    k = _rand(jax.random.PRNGKey(8), (B, K, S, hd), jnp.float32) * 0.4
    v = _rand(jax.random.PRNGKey(9), (B, K, S, hd), jnp.float32)
    length = jnp.asarray([S, S // 2], jnp.int32)     # one full, one partial
    got = pul_decode_attention(q, k, v, length, cfg=PULConfig(distance=distance),
                               bs=32, softcap=softcap)
    want = ref.decode_attention_ref(q, k, v, length, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)

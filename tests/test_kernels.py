"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import IssueStrategy, PULConfig
from repro.kernels import (
    pul_attention,
    pul_filter,
    pul_gather,
    pul_matmul,
    pul_page_gather,
    pul_paged_decode_attention,
    pul_sum,
    ref,
)

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# --------------------------------------------------------------------- sum
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("distance,strategy", [
    (1, IssueStrategy.BATCH), (4, IssueStrategy.BATCH),
    (3, IssueStrategy.SEQUENTIAL), (16, IssueStrategy.BATCH)])
@pytest.mark.parametrize("rows_per_req", [1, 4])
def test_pul_sum(dtype, distance, strategy, rows_per_req):
    R, W, n = 32, 128, 18
    data = _rand(KEY, (R * rows_per_req, W), dtype)
    trace = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, R, jnp.int32)
    cfg = PULConfig(distance=distance, strategy=strategy)
    got = pul_sum(data, trace, cfg=cfg, rows_per_req=rows_per_req)
    rows = jnp.concatenate([jnp.arange(rows_per_req) + t * rows_per_req
                            for t in trace])
    want = ref.sum_ref(data, rows)
    np.testing.assert_allclose(got, want, rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


# ------------------------------------------------------------------ gather
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("distance", [1, 2, 8])
def test_pul_gather(dtype, distance):
    R, W, n = 64, 256, 40
    if dtype == jnp.int32:
        table = jax.random.randint(KEY, (R, W), -100, 100, jnp.int32)
    else:
        table = _rand(KEY, (R, W), dtype)
    trace = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, R, jnp.int32)
    got = pul_gather(table, trace, cfg=PULConfig(distance=distance))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.gather_ref(table, trace)))


# ------------------------------------------------------------------ matmul
@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("shape,blocks", [
    ((128, 128, 128), (64, 64, 64)),
    ((128, 256, 384), (64, 128, 128)),
    ((64, 512, 128), (64, 64, 128)),
])
@pytest.mark.parametrize("distance", [1, 3])
def test_pul_matmul(dtype, rtol, shape, blocks, distance):
    M, K, N = shape
    bm, bk, bn = blocks
    a = _rand(KEY, (M, K), dtype)
    b = _rand(jax.random.PRNGKey(3), (K, N), dtype)
    got = pul_matmul(a, b, cfg=PULConfig(distance=distance), bm=bm, bk=bk, bn=bn)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol * 10)


# --------------------------------------------------------------- attention
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("gqa", [1, 4])
@pytest.mark.parametrize("softcap,window", [(None, None), (8.0, None), (None, 24)])
def test_pul_attention(dtype, tol, gqa, softcap, window):
    B, K, T, S, hd = 2, 2, 64, 64, 32
    H = K * gqa
    q = _rand(KEY, (B, H, T, hd), dtype) * 0.3
    k = _rand(jax.random.PRNGKey(4), (B, K, S, hd), dtype) * 0.3
    v = _rand(jax.random.PRNGKey(5), (B, K, S, hd), dtype)
    got = pul_attention(q, k, v, cfg=PULConfig(distance=2), bt=32, bs=16,
                        softcap=softcap, window=window)
    want = ref.attention_ref(q, k, v, softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_pul_attention_uneven_kv_tail():
    """S not a multiple of bs exercises the in-kernel tail mask."""
    B, H, T, S, hd = 1, 2, 32, 48, 16
    q = _rand(KEY, (B, H, T, hd), jnp.float32) * 0.5
    k = _rand(jax.random.PRNGKey(6), (B, H, S, hd), jnp.float32) * 0.5
    v = _rand(jax.random.PRNGKey(7), (B, H, S, hd), jnp.float32)
    got = pul_attention(q, k, v, bt=32, bs=32)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


# ------------------------------------------------------------------ filter
@pytest.mark.parametrize("materialize", [False, True])
@pytest.mark.parametrize("distance", [2, 8])
def test_pul_filter(materialize, distance):
    N, W = 512, 64
    data = _rand(KEY, (N, W), jnp.float32)
    got = pul_filter(data, 0.25, cfg=PULConfig(distance=distance),
                     rows_per_block=128, materialize=materialize)
    if materialize:
        want = ref.filter_materialize_ref(data, 0.25)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        want = ref.filter_ref(data, 0.25)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------- property sweep
@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 40),
    rows=st.integers(8, 64),
    d=st.integers(1, 8),
    seq=st.booleans(),
)
def test_gather_roundtrip_property(n, rows, d, seq):
    """gather(table, trace) == table[trace] for arbitrary traces/knobs."""
    table = jax.random.normal(jax.random.PRNGKey(n), (rows, 128), jnp.float32)
    trace = jax.random.randint(jax.random.PRNGKey(n + 1), (n,), 0, rows, jnp.int32)
    cfg = PULConfig(distance=d, strategy=(IssueStrategy.SEQUENTIAL if seq
                                          else IssueStrategy.BATCH))
    got = pul_gather(table, trace, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(table[trace]))


# --------------------------------------------------------------- paged paths
@pytest.mark.parametrize("distance", [1, 4])
@pytest.mark.parametrize("P", [8, 16])
def test_pul_page_gather(distance, P):
    """Page-table gather == store[page_table] (the serving assembly path)."""
    NP, F = 12, 128
    store = _rand(KEY, (NP, P, F), jnp.float32)
    pt = jax.random.randint(jax.random.PRNGKey(11), (3, 4), 0, NP, jnp.int32)
    got = pul_page_gather(store, pt, cfg=PULConfig(distance=distance))
    want = store[pt].reshape(3, 4 * P, F)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("gqa", [1, 4])
@pytest.mark.parametrize("distance", [1, 3])
def test_pul_paged_decode_attention(gqa, distance):
    """Decode attention straight over scattered pages == dense oracle over
    the assembled contiguous cache (mixed fill levels incl. partial pages)."""
    B, K, P, npg, hd = 2, 2, 8, 4, 16
    H, S, NP = K * gqa, P * npg, 11
    kp = _rand(jax.random.PRNGKey(1), (NP, K, P, hd), jnp.float32) * 0.4
    vp = _rand(jax.random.PRNGKey(2), (NP, K, P, hd), jnp.float32)
    pt = jnp.asarray(np.random.default_rng(0).permutation(NP)[:B * npg]
                     .reshape(B, npg), jnp.int32)
    q = _rand(jax.random.PRNGKey(3), (B, H, hd), jnp.float32) * 0.4
    lengths = jnp.asarray([S, S // 2 + 3], jnp.int32)
    got = pul_paged_decode_attention(q, kp, vp, pt, lengths,
                                     cfg=PULConfig(distance=distance))
    kd = kp[pt].transpose(0, 2, 1, 3, 4).reshape(B, K, S, hd)
    vd = vp[pt].transpose(0, 2, 1, 3, 4).reshape(B, K, S, hd)
    want = ref.decode_attention_ref(q, kd, vd, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------- decode attention
@pytest.mark.parametrize("gqa", [1, 4])
@pytest.mark.parametrize("distance", [1, 4])
@pytest.mark.parametrize("softcap", [None, 10.0])
def test_pul_decode_attention(gqa, distance, softcap):
    from repro.kernels import pul_decode_attention
    B, K, S, hd = 2, 2, 96, 32
    H = K * gqa
    q = _rand(KEY, (B, H, hd), jnp.float32) * 0.4
    k = _rand(jax.random.PRNGKey(8), (B, K, S, hd), jnp.float32) * 0.4
    v = _rand(jax.random.PRNGKey(9), (B, K, S, hd), jnp.float32)
    length = jnp.asarray([S, S // 2], jnp.int32)     # one full, one partial
    got = pul_decode_attention(q, k, v, length, cfg=PULConfig(distance=distance),
                               bs=32, softcap=softcap)
    want = ref.decode_attention_ref(q, k, v, length, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)

"""PUL Pallas emitter invariants (interpret mode): stream correctness over
the (distance, slots, strategy) knob space, unload single-ownership."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (
    IssueStrategy,
    PULConfig,
    PreloadStream,
    UnloadStream,
    pul_loop,
    ring_scratch,
)


def _copy_kernel(cfg, n, blk):
    def kernel(idx_smem, x_hbm, acc_ref, o_hbm, pbuf, psem, ubuf, usem):
        pre = PreloadStream(x_hbm, pbuf, psem,
                            index_map=lambda i: (idx_smem[i], 0),
                            cfg=cfg, n_blocks=n)
        unl = UnloadStream(o_hbm, ubuf, usem,
                           index_map=lambda i: (i, 0), cfg=cfg, n_blocks=n)

        def body(i, views, carry):
            row = views[0][0, :]
            slot = unl.slot(i)
            slot[0, :] = row * 2.0
            unl.issue(i)
            return carry + jnp.sum(row)

        acc = pul_loop(n, [pre], body, jnp.float32(0.0), cfg, unloads=[unl])
        acc_ref[0] = acc
    return kernel


def _run(cfg, x, idx):
    n = idx.shape[0]
    blk = x.shape[1]
    return pl.pallas_call(
        _copy_kernel(cfg, n, blk),
        out_shape=(jax.ShapeDtypeStruct((1,), jnp.float32),
                   jax.ShapeDtypeStruct((n, blk), jnp.float32)),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=(pl.BlockSpec(memory_space=pltpu.SMEM),
                   pl.BlockSpec(memory_space=pl.ANY)),
        scratch_shapes=[*ring_scratch(cfg, (1, blk), jnp.float32),
                        *ring_scratch(cfg, (1, blk), jnp.float32)],
        interpret=True,
    )(idx, x)


@pytest.mark.parametrize("strategy", [IssueStrategy.BATCH,
                                      IssueStrategy.SEQUENTIAL])
@pytest.mark.parametrize("distance", [1, 2, 5, 16])
def test_stream_copy_all_knobs(strategy, distance):
    cfg = PULConfig(distance=distance, strategy=strategy, block_shape=(1, 128))
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 128), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(1), (33,), 0, 64, jnp.int32)
    acc, out = _run(cfg, x, idx)
    np.testing.assert_allclose(acc[0], x[idx].sum(), rtol=1e-4, atol=2e-3)
    np.testing.assert_allclose(out, x[idx] * 2.0)


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(1, 12),
    n=st.integers(1, 40),
    extra_slots=st.integers(0, 3),
    seq=st.booleans(),
)
def test_stream_property_any_shape(d, n, extra_slots, seq):
    """Result is knob-independent: any (distance, slots, strategy, n) gives
    exactly the oracle (the paper's knobs change WHEN bytes move, not WHAT)."""
    strategy = IssueStrategy.SEQUENTIAL if seq else IssueStrategy.BATCH
    base = PULConfig(distance=d, strategy=strategy).num_slots
    cfg = PULConfig(distance=d, strategy=strategy, slots=base + extra_slots,
                    block_shape=(1, 128))
    x = jax.random.normal(jax.random.PRNGKey(n), (32, 128), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(d), (n,), 0, 32, jnp.int32)
    acc, out = _run(cfg, x, idx)
    # near-cancelling sums need an absolute floor (fp32 accumulation order)
    np.testing.assert_allclose(acc[0], x[idx].sum(), rtol=1e-4, atol=2e-3)
    np.testing.assert_allclose(out, x[idx] * 2.0)


def test_n_blocks_smaller_than_distance():
    cfg = PULConfig(distance=16, block_shape=(1, 128))
    x = jnp.ones((8, 128), jnp.float32)
    idx = jnp.arange(3, dtype=jnp.int32)
    acc, out = _run(cfg, x, idx)
    np.testing.assert_allclose(acc[0], 3 * 128.0)


def test_vmem_budget_guard():
    cfg = PULConfig(distance=64, block_shape=(1024, 1024))
    with pytest.raises(ValueError, match="VMEM budget"):
        ring_scratch(cfg, (1024, 1024), jnp.float32)

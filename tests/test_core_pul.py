"""Unit + property tests for the PUL core (config, DMA model, planner)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    DMAEngine,
    DRAM,
    IssueStrategy,
    MICROBLAZE,
    NVM,
    PULConfig,
    optimal_distance,
    plan_stream,
    predicted_speedup,
    speedup,
)


def test_config_validation():
    with pytest.raises(ValueError):
        PULConfig(distance=0)
    with pytest.raises(ValueError):
        PULConfig(distance=65)          # exceeds the paper's 64-deep FIFO
    with pytest.raises(ValueError):
        PULConfig(distance=4, slots=2)  # block must stay resident
    assert PULConfig(distance=4).num_slots == 8          # batch: 2d
    assert PULConfig(distance=4,
                     strategy=IssueStrategy.SEQUENTIAL).num_slots == 5


def _stream_kwargs(**over):
    kw = dict(n_blocks=256, block_bytes=64, compute_flops_per_block=16)
    kw.update(over)
    return kw


def test_distance_improves_then_plateaus():
    """Paper Fig 5-A: time falls with distance, then plateaus."""
    eng = DMAEngine(NVM, MICROBLAZE)
    times = [eng.run_stream(PULConfig(distance=d), **_stream_kwargs()).total_time
             for d in (1, 2, 4, 8, 16, 32)]
    assert times[0] > times[-1]
    for a, b in zip(times, times[1:]):
        assert b <= a * 1.001           # monotone (within epsilon)
    assert times[-2] <= times[-1] * 1.01  # plateau: d16 ~ d32


def test_plateau_matches_planner():
    """The sim's plateau is at the planner's analytic d*."""
    eng = DMAEngine(NVM, MICROBLAZE)
    plan = plan_stream(block_bytes=64, flops_per_block=16, tier=NVM,
                       pe=MICROBLAZE)
    d_star = plan.cfg.distance
    t_star = eng.run_stream(PULConfig(distance=d_star), **_stream_kwargs()).total_time
    t_deep = eng.run_stream(PULConfig(distance=min(64, 4 * d_star)),
                            **_stream_kwargs()).total_time
    assert t_star <= t_deep * 1.15      # no more than 15% off the deep-queue time


def test_interleave_speedup_positive_and_nvm_beats_dram():
    """Paper Exp 1: speedup > 1; higher-latency NVM gains more."""
    s_nvm = speedup(DMAEngine(NVM, MICROBLAZE), PULConfig(distance=16),
                    **_stream_kwargs())
    s_dram = speedup(DMAEngine(DRAM, MICROBLAZE), PULConfig(distance=16),
                     **_stream_kwargs())
    assert s_nvm > 1.5
    assert s_dram > 1.2
    assert s_nvm > s_dram


def test_batch_no_worse_than_sequential_below_plateau():
    """Paper Fig 5-D."""
    eng = DMAEngine(NVM, MICROBLAZE)
    for d in (2, 4, 8):
        tb = eng.run_stream(PULConfig(distance=d, strategy=IssueStrategy.BATCH),
                            **_stream_kwargs()).total_time
        ts = eng.run_stream(
            PULConfig(distance=d, strategy=IssueStrategy.SEQUENTIAL),
            **_stream_kwargs()).total_time
        assert tb <= ts * 1.02


def test_unload_interleaving_beats_sync_flush():
    """Paper Exp 5: async unload vs synchronous flush."""
    eng = DMAEngine(NVM, MICROBLAZE)
    kw = _stream_kwargs(unload_bytes_per_block=64)
    t_async = eng.run_stream(PULConfig(distance=8, unload_distance=1), **kw).total_time
    t_sync = eng.run_stream(PULConfig(distance=8, unload_distance=0), **kw).total_time
    assert t_async < t_sync


def test_multi_pe_bandwidth_saturation():
    """Paper Exp 4/Fig 6: aggregate bandwidth caps scaling."""
    eng = DMAEngine(NVM, MICROBLAZE)
    single = eng.run_stream(PULConfig(distance=16), **_stream_kwargs())
    s1 = eng.scale_to_pes(single, 1)
    s14 = eng.scale_to_pes(single, 14)
    assert s14.total_time >= s1.total_time          # dilation only grows
    assert s14.io_throughput <= NVM.bandwidth * 1.01


@settings(max_examples=50, deadline=None)
@given(
    d=st.integers(1, 64),
    block=st.sampled_from([64, 256, 1024, 4096]),
    flops=st.integers(1, 10_000),
)
def test_pipelining_never_hurts(d, block, flops):
    """Interleaved execution is never slower than phase-separated (the
    paper's core claim, as an invariant over the knob space)."""
    eng = DMAEngine(NVM, MICROBLAZE)
    kw = dict(n_blocks=64, block_bytes=block, compute_flops_per_block=flops)
    assert speedup(eng, PULConfig(distance=d), **kw) >= 0.999


@settings(max_examples=50, deadline=None)
@given(
    block=st.sampled_from([64, 512, 4096]),
    flops=st.integers(1, 100_000),
)
def test_planner_distance_optimal_within_tolerance(block, flops):
    """Simulated time at d* is within 10% of the best over all distances."""
    eng = DMAEngine(NVM, MICROBLAZE)
    kw = dict(n_blocks=128, block_bytes=block, compute_flops_per_block=flops)
    plan = plan_stream(block_bytes=block, flops_per_block=flops, tier=NVM,
                       pe=MICROBLAZE)
    t_star = eng.run_stream(PULConfig(distance=plan.cfg.distance), **kw).total_time
    t_best = min(eng.run_stream(PULConfig(distance=d), **kw).total_time
                 for d in (1, 2, 4, 8, 16, 32, 64))
    assert t_star <= t_best * 1.10


def test_predicted_speedup_orders_tiers():
    s_nvm = predicted_speedup(block_bytes=64, flops_per_block=16,
                              tier=NVM, pe=MICROBLAZE)
    s_dram = predicted_speedup(block_bytes=64, flops_per_block=16,
                               tier=DRAM, pe=MICROBLAZE)
    assert s_nvm > s_dram > 1.0


def test_fifo_backpressure():
    """A distance > fifo_depth is rejected; at depth the PE stalls but the
    schedule stays correct (completion count == n_blocks)."""
    eng = DMAEngine(NVM, MICROBLAZE, fifo_depth=4)
    st_ = eng.run_stream(PULConfig(distance=4, fifo_depth=4),
                         **_stream_kwargs(n_blocks=32))
    assert st_.total_time > 0
    with pytest.raises(ValueError):
        PULConfig(distance=8, fifo_depth=4)

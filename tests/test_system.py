"""End-to-end behaviour: training convergence, accum equivalence, pipeline
emitter invariants, dry-run machinery on a tiny mesh (subprocess)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import OptimizerConfig, adamw_init
from repro.data import DataConfig, TokenPipeline


def test_training_loss_decreases():
    """A tiny model must overfit a repeated batch quickly."""
    cfg = get_config("qwen3-1.7b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(
        cfg, OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=40)))
    data = TokenPipeline(DataConfig(global_batch=4, seq_len=32,
                                    vocab_size=cfg.vocab_size, seed=0))
    batch = next(data)
    losses = []
    for _ in range(30):
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[::6]
    assert np.isfinite(losses).all()


def test_grad_accum_equivalent_to_full_batch():
    """accum=4 over a batch == accum=1 (same grads => same update)."""
    cfg = get_config("musicgen-large").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    data = TokenPipeline(DataConfig(global_batch=8, seq_len=16,
                                    vocab_size=cfg.vocab_size, seed=2,
                                    frontend_tokens=cfg.frontend_tokens,
                                    d_model=cfg.d_model))
    batch = next(data)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=0)
    p1, _, m1 = jax.jit(make_train_step(cfg, ocfg, accum=1))(
        params, adamw_init(params), batch)
    p4, _, m4 = jax.jit(make_train_step(cfg, ocfg, accum=4))(
        params, adamw_init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=2e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)


def test_trainer_cli_runs_and_resumes(tmp_path):
    """The real launcher: run 6 steps, kill, rerun -> resumes from ckpt."""
    env = dict(os.environ, PYTHONPATH="src")
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-1.7b",
            "--reduced", "--steps", "6", "--batch", "2", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
            "--log-every", "2"]
    out1 = subprocess.run(args[:10] + ["--ckpt-dir", str(tmp_path),
                                       "--ckpt-every", "3", "--log-every", "2"],
                          env=env, cwd="/root/repo", capture_output=True,
                          text=True, timeout=600)
    assert out1.returncode == 0, out1.stderr[-2000:]
    out2 = subprocess.run(args, env=env, cwd="/root/repo",
                          capture_output=True, text=True, timeout=600)
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert "resumed from step" in out2.stdout


def test_dryrun_collective_parser():
    from repro.launch.dryrun import collective_bytes_from_hlo
    hlo = """
  %ag = bf16[256,4096]{1,0} all-gather(%x), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%y), replica_groups=[2,256]<=[512]
  %agd = bf16[8]{0} all-gather-done(%ag)
  %cp = bf16[128,128]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    total, kinds, by_depth = collective_bytes_from_hlo(hlo, 512)
    ag = 256 * 4096 * 2 * 15 / 16
    ar = 1024 * 4 * 2 * 255 / 256
    cp = 128 * 128 * 2
    assert kinds["all-gather"] == int(ag)
    assert kinds["all-reduce"] == int(ar)
    assert kinds["collective-permute"] == int(cp)
    assert total == int(ag) + int(ar) + int(cp)
    assert by_depth == {0: int(ag) + int(ar) + int(cp)}


def test_dryrun_tiny_mesh_subprocess():
    """Real lower+compile of a reduced arch on a forced 4-device host mesh
    (exercises the same cell_specs/shardings path as the 512-dev dry-run)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.configs import get_config, SHAPES
from repro.launch import steps as S
from repro.models import module as M
import dataclasses
cfg = get_config("gemma2-27b").reduced()
from repro.launch.mesh import set_mesh
mesh = jax.make_mesh((2, 2), ("data", "model"))
with set_mesh(mesh):
    fn = S.make_train_step(cfg, accum=2)
    from repro.models import zoo
    model = zoo.build_model(cfg)
    aparams = model.abstract_params()
    pspecs = M.param_specs(model.params, mesh)
    opt = {"m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), aparams),
           "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), aparams),
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    ospecs = {"m": pspecs, "v": pspecs, "step": jax.sharding.PartitionSpec()}
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
             "targets": jax.ShapeDtypeStruct((4, 32), jnp.int32),
             "loss_mask": jax.ShapeDtypeStruct((4, 32), jnp.float32)}
    bspecs = {"tokens": jax.sharding.PartitionSpec("data"),
              "targets": jax.sharding.PartitionSpec("data"),
              "loss_mask": jax.sharding.PartitionSpec("data")}
    P = jax.sharding.PartitionSpec
    NS = jax.sharding.NamedSharding
    to_shard = lambda tree: jax.tree.map(lambda s: NS(mesh, s), tree,
                                         is_leaf=lambda x: isinstance(x, P))
    compiled = jax.jit(fn, in_shardings=(
        to_shard(pspecs), to_shard(ospecs), to_shard(bspecs))).lower(
        aparams, opt, batch).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0]
    assert ca.get("flops", 0) > 0
    print("TINY_DRYRUN_OK", int(compiled.memory_analysis().temp_size_in_bytes))
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         cwd="/root/repo", capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "TINY_DRYRUN_OK" in out.stdout

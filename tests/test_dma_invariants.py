"""Invariant tests for the discrete-event DMA twin (core/dma.py):
FIFO depth, per-direction wire serialization, BATCH-vs-SEQUENTIAL issue
ordering (paper Fig. 5-D), and the KV-page workload's latency hiding at the
planner's d* (the paged serving engine's modeled claim)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    DMAEngine,
    IssueStrategy,
    KVPageWorkload,
    MICROBLAZE,
    NVM,
    PULConfig,
    REMOTE_HBM,
    TPU_V5E_VPU,
    kv_page_latency_hidden,
    optimal_distance,
    plan_kv_page_stream,
    plan_stream,
    run_kv_page_workload,
)

EPS = 1e-12


def _run(eng, cfg, **kw):
    base = dict(n_blocks=96, block_bytes=256, compute_flops_per_block=64)
    base.update(kw)
    return eng.run_stream(cfg, **base)


# ------------------------------------------------------------------- FIFO
@settings(max_examples=30, deadline=None)
@given(
    depth=st.integers(1, 16),
    block=st.sampled_from([64, 1024, 8192]),
    flops=st.integers(1, 5_000),
    seq=st.booleans(),
)
def test_fifo_never_exceeds_depth(depth, block, flops, seq):
    """Outstanding requests never exceed fifo_depth, whatever the knobs —
    a full FIFO stalls the PE instead (paper §2 HW contract)."""
    eng = DMAEngine(NVM, MICROBLAZE, fifo_depth=depth)
    cfg = PULConfig(
        distance=depth, fifo_depth=depth,
        strategy=IssueStrategy.SEQUENTIAL if seq else IssueStrategy.BATCH)
    _run(eng, cfg, block_bytes=block, compute_flops_per_block=flops,
         unload_bytes_per_block=block // 2)
    pre, unl = eng.last_channels
    assert pre.max_outstanding <= depth
    assert unl.max_outstanding <= depth


# ----------------------------------------------------------- serialization
@settings(max_examples=30, deadline=None)
@given(
    d=st.integers(1, 32),
    block=st.sampled_from([64, 512, 4096]),
    flops=st.integers(1, 20_000),
    seq=st.booleans(),
)
def test_per_direction_wire_serialization(d, block, flops, seq):
    """Each direction's channel is ONE serial wire: transfer intervals never
    overlap and respect enqueue order; a transfer never starts before its
    enqueue."""
    eng = DMAEngine(NVM, MICROBLAZE)
    cfg = PULConfig(
        distance=d,
        strategy=IssueStrategy.SEQUENTIAL if seq else IssueStrategy.BATCH)
    _run(eng, cfg, block_bytes=block, compute_flops_per_block=flops,
         unload_bytes_per_block=block)
    for ch in eng.last_channels:
        prev_end = 0.0
        for enq, start, end in ch.wire_log:    # log is in enqueue order
            assert start >= enq - EPS
            assert start >= prev_end - EPS     # serial, FIFO order
            assert end >= start
            prev_end = end


# ------------------------------------------------------------- Fig. 5-D
@settings(max_examples=30, deadline=None)
@given(
    block=st.sampled_from([64, 256, 2048]),
    flops=st.integers(1, 2_000),
)
def test_batch_issue_throughput_below_plateau(block, flops):
    """Below the latency plateau (d < d*), BATCH issue keeps the serial
    channel gap-free: I/O throughput >= SEQUENTIAL (paper Fig. 5-D)."""
    eng = DMAEngine(NVM, MICROBLAZE)
    plan = plan_stream(block_bytes=block, flops_per_block=flops,
                       tier=NVM, pe=MICROBLAZE)
    if plan.cfg.distance <= 1:
        return                      # no "below the plateau" region exists
    for d in sorted({1, plan.cfg.distance // 2, plan.cfg.distance - 1}):
        if d < 1:
            continue
        kw = dict(block_bytes=block, compute_flops_per_block=flops)
        tb = _run(eng, PULConfig(distance=d), **kw)
        ts = _run(eng, PULConfig(distance=d,
                                 strategy=IssueStrategy.SEQUENTIAL), **kw)
        assert tb.io_throughput >= ts.io_throughput * 0.98


# ------------------------------------------------------ KV-page workload
def test_kv_page_workload_dstar_hides_90pct_latency():
    """Acceptance: at steady state the planned preload distance hides >=90%
    of modeled page-restore latency, on both the paper's NDP tiers and the
    TPU serving tiers (remote-HBM cold tier)."""
    cases = [
        # paper tiers: weak PE, compute-bound pages -> full hiding
        (NVM, MICROBLAZE, 16, 128, 1),
        # TPU serving tiers: decode attention is bandwidth/latency-bound;
        # the 3us access latency is the hideable part
        (REMOTE_HBM, TPU_V5E_VPU, 16, 128, 4),
        (REMOTE_HBM, TPU_V5E_VPU, 32, 512, 8),
    ]
    for tier, pe, P, F, gqa in cases:
        eng = DMAEngine(tier, pe)
        plan = plan_kv_page_stream(page_tokens=P, kv_features=F,
                                   tier=tier, pe=pe, gqa_group=gqa)
        wl = KVPageWorkload(
            page_bytes=P * F * 2,
            flops_per_page=4.0 * P * F * gqa,
            pages_per_step=4, steps=256)
        hidden = kv_page_latency_hidden(eng, wl, distance=plan.cfg.distance)
        assert hidden >= 0.90, (tier.name, pe.name, hidden)


def test_kv_page_workload_dstar_beats_d1():
    """When the plateau is beyond d=1, planning at d* hides strictly more
    restore latency than a depth-1 pipeline."""
    tier, pe = REMOTE_HBM, TPU_V5E_VPU
    plan = plan_kv_page_stream(page_tokens=16, kv_features=128,
                               tier=tier, pe=pe, gqa_group=4)
    assert plan.cfg.distance > 1
    eng = DMAEngine(tier, pe)
    wl = KVPageWorkload(page_bytes=16 * 128 * 2,
                        flops_per_page=4.0 * 16 * 128 * 4,
                        pages_per_step=4, steps=256)
    h_star = kv_page_latency_hidden(eng, wl, distance=plan.cfg.distance)
    h_one = kv_page_latency_hidden(eng, wl, distance=1)
    assert h_star > h_one


def test_kv_page_workload_stats_accounting():
    eng = DMAEngine(NVM, MICROBLAZE)
    wl = KVPageWorkload(page_bytes=4096, flops_per_page=1024,
                        pages_per_step=2, steps=32,
                        unload_pages_per_step=1)
    stats = run_kv_page_workload(eng, wl, distance=8)
    assert stats.bytes_in == wl.n_pages * 4096
    assert stats.bytes_out > 0
    assert stats.total_time >= stats.compute_time

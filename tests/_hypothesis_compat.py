"""Deterministic stand-in for `hypothesis` when the real package is absent.

The test suite uses a small slice of the hypothesis API:

    from hypothesis import given, settings, strategies as st
    @settings(max_examples=N, deadline=None)
    @given(x=st.integers(0, 8), y=st.sampled_from([...]), z=st.booleans())
    def test_...(x, y, z): ...

This module implements exactly that slice with *deterministic* sampling
(seeded per test by the test's qualified name), so property tests run — and
reproduce — on machines without hypothesis installed. `tests/conftest.py`
installs it into ``sys.modules["hypothesis"]`` only when the real library is
missing; when hypothesis is available it is used unchanged.

Not supported (and not used by this suite): shrinking, assume(), stateful
testing, composite strategies.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import random
import types

DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    """Base strategy: something that can draw a value from an RNG."""

    def example(self, rng: random.Random):
        raise NotImplementedError

    def map(self, fn):
        return _MappedStrategy(self, fn)


class _IntegersStrategy(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.min_value = min_value
        self.max_value = max_value

    def example(self, rng):
        return rng.randint(self.min_value, self.max_value)

    def _boundary_examples(self):
        return [self.min_value, self.max_value]


class _SampledFromStrategy(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty collection")

    def example(self, rng):
        return rng.choice(self.elements)

    def _boundary_examples(self):
        return [self.elements[0], self.elements[-1]]


class _BooleansStrategy(SearchStrategy):
    def example(self, rng):
        return rng.random() < 0.5

    def _boundary_examples(self):
        return [False, True]


class _FloatsStrategy(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.min_value = min_value
        self.max_value = max_value

    def example(self, rng):
        return rng.uniform(self.min_value, self.max_value)

    def _boundary_examples(self):
        return [self.min_value, self.max_value]


class _ListsStrategy(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=10):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.example(rng) for _ in range(n)]

    def _boundary_examples(self):
        return [[]] if self.min_size == 0 else []


class _MappedStrategy(SearchStrategy):
    def __init__(self, base, fn):
        self.base = base
        self.fn = fn

    def example(self, rng):
        return self.fn(self.base.example(rng))

    def _boundary_examples(self):
        base = getattr(self.base, "_boundary_examples", lambda: [])()
        return [self.fn(v) for v in base]


def integers(min_value=0, max_value=2**31 - 1):
    return _IntegersStrategy(min_value, max_value)


def sampled_from(elements):
    return _SampledFromStrategy(elements)


def booleans():
    return _BooleansStrategy()


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _FloatsStrategy(min_value, max_value)


def lists(elements, min_size=0, max_size=10):
    return _ListsStrategy(elements, min_size=min_size, max_size=max_size)


strategies = types.ModuleType("hypothesis.strategies")
strategies.SearchStrategy = SearchStrategy
strategies.integers = integers
strategies.sampled_from = sampled_from
strategies.booleans = booleans
strategies.floats = floats
strategies.lists = lists


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator: records the example budget on the (already-@given) fn."""

    def apply(fn):
        fn._shim_settings = {"max_examples": max_examples}
        return fn

    return apply


def _corner_cases(arg_strategies, kw_strategies):
    """First examples: all-min and all-max corners (cheap edge coverage)."""
    corners = []
    for pick in (0, -1):
        try:
            args = [s._boundary_examples()[pick] for s in arg_strategies]
            kw = {k: s._boundary_examples()[pick]
                  for k, s in kw_strategies.items()}
        except (AttributeError, IndexError):
            return []
        corners.append((args, kw))
    return corners


def given(*arg_strategies, **kw_strategies):
    """Decorator: runs the test over deterministically sampled examples.

    The RNG seed derives from the test's qualified name so every run (and
    every machine) sees the same example sequence. The first two examples
    pin the all-min / all-max corners of the strategy space.
    """

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*fixture_args, **fixture_kw):
            n = getattr(wrapper, "_shim_settings",
                        {"max_examples": DEFAULT_MAX_EXAMPLES})["max_examples"]
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            examples = itertools.chain(
                _corner_cases(arg_strategies, kw_strategies),
                ((
                    [s.example(rng) for s in arg_strategies],
                    {k: s.example(rng) for k, s in kw_strategies.items()},
                ) for _ in iter(int, 1)),
            )
            for _, (args, kw) in zip(range(n), examples):
                try:
                    fn(*fixture_args, *args, **fixture_kw, **kw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__qualname__}): "
                        f"args={args} kwargs={kw}") from e
            return None

        # pytest must not inject fixtures for the strategy-driven params
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in kw_strategies]
        params = params[:len(params) - len(arg_strategies)] if arg_strategies else params
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper

    return decorate


def install(sys_modules) -> None:
    """Register this shim as the `hypothesis` package in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__version__ = "0.0-shim"
    mod._is_pul_shim = True
    sys_modules["hypothesis"] = mod
    sys_modules["hypothesis.strategies"] = strategies

"""Page-lifecycle sanitizer, DMA-plan verifier, and PoolMetrics.validate.

Each violation class gets a deliberately broken driver — a real
``KVPagePool`` pushed through a buggy call sequence where the pool can
physically reach the bug, a synthetic trace (``TraceLog.emit``) where the
current pool implementation is already correct by construction and only a
hypothetical regression could emit the pattern. Every test asserts the
exact rule, the offending page id, and the event at which the break was
reported — provenance is the deliverable, not just a boolean.

The regression classes from PRs 1–3 are covered generically:

  * same-step evict/restore churn (PR 2's allocation-steals-fresh-restore
    bug) -> ``evict-restore-churn`` from a REAL pool driver;
  * decode scatter into the reserved zero frame (PR 1/2's page-table
    corruption class) -> ``write-to-non-hot-frame`` from a REAL pool driver;
  * shared-prefix refcount drift (PR 1's sharing bug class) ->
    ``refcount-underflow`` / ``refcount-leak``.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    EventKind,
    LifecycleChecker,
    LifecycleViolationError,
    PlanError,
    TraceLog,
    check_page_trace,
    format_violations,
    verify_kv_page_plan,
    verify_stream_plan,
)
from repro.core import (
    DMAEngine,
    IssueStrategy,
    PULConfig,
    TIERS,
    PES,
    plan_kv_page_stream,
)
from repro.serving import KVPagePool, PageConfig, PoolMetrics

pytestmark = [pytest.mark.paged, pytest.mark.analysis]

FEATURES = 32


def _pool(hot_frames=6, **kw) -> KVPagePool:
    """Traced pool, small enough to force real evictions (capacity =
    hot_frames - 2 reserved)."""
    kw.setdefault("page_tokens", 8)
    return KVPagePool(PageConfig(hot_frames=hot_frames, trace=True, **kw),
                      FEATURES)


def _rows(n=1):
    return jnp.ones((n, FEATURES), jnp.bfloat16)


def _only(violations, rule):
    """The single violation carrying `rule` (asserting there is exactly 1)."""
    hits = [v for v in violations if v.rule == rule]
    assert len(hits) == 1, format_violations(violations)
    return hits[0]


# ======================================================================== #
# clean traces: the real pool, driven correctly, produces zero violations
# ======================================================================== #

def test_clean_lifecycle_has_no_violations():
    pool = _pool()
    a = pool.alloc()
    b = pool.alloc(shared_key=("sys", 0))
    assert pool.lookup_shared(("sys", 0)) == b      # REF via prefix sharing
    pool.note_deadline([a, b], 40.0)
    pool.evict(a)                                   # explicit spill
    pool.ensure_hot([a, b])                         # restore a
    pool.write_page(a, _rows(8), n_valid=8)
    pool.frames_of([a, b])                          # READ events
    pool.unref(a)
    pool.unref(b)
    pool.unref(b)                                   # shared ref drops to 0
    violations = check_page_trace(pool.trace, final=True)
    assert violations == [], format_violations(violations)


def test_trace_off_by_default_means_no_trace_object():
    """Zero-overhead contract: an untraced pool never builds events."""
    pool = KVPagePool(PageConfig(page_tokens=8, hot_frames=6), FEATURES)
    assert pool.trace is None
    pid = pool.alloc()
    pool.ensure_hot([pid])
    assert pool.trace is None


# ======================================================================== #
# violation classes, one broken driver each
# ======================================================================== #

def test_refcount_underflow_detected():
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=7, frame=2, refcount=1)
    log.emit(1, EventKind.UNREF, pid=7, refcount=0)
    log.emit(1, EventKind.UNREF, pid=7, refcount=-1)    # the bug
    v = _only(check_page_trace(log), "refcount-underflow")
    assert v.pid == 7
    assert v.event.kind is EventKind.UNREF and v.event.seq == 2
    assert [e.kind for e in v.history] == [
        EventKind.ALLOC, EventKind.UNREF, EventKind.UNREF]


def test_unref_after_free_is_underflow():
    """Shared-prefix drift (PR 1 class): one more unref than refs."""
    pool = _pool()
    pid = pool.alloc(shared_key=("p", 1))
    pool.unref(pid)                                 # freed here
    pool.trace.emit(1, EventKind.UNREF, pid=pid, refcount=-1)  # the drift
    v = _only(check_page_trace(pool.trace), "refcount-underflow")
    assert v.pid == pid and v.event.kind is EventKind.UNREF


def test_refcount_leak_detected_at_finalize():
    pool = _pool()
    kept = pool.alloc()
    freed = pool.alloc()
    pool.unref(freed)
    violations = check_page_trace(pool.trace, final=True)
    v = _only(violations, "refcount-leak")
    assert v.pid == kept
    # without finalize the live page is not (yet) a violation
    assert check_page_trace(pool.trace) == []


def test_use_after_evict_detected_on_gather():
    pool = _pool()
    pid = pool.alloc()
    pool.evict(pid)
    with pytest.raises(AssertionError, match="cold at gather"):
        pool.frames_of([pid])           # READ event lands before the assert
    v = _only(check_page_trace(pool.trace), "use-after-evict")
    assert v.pid == pid
    assert v.event.kind is EventKind.READ
    assert [e.kind for e in v.history][-2:] == [EventKind.EVICT,
                                                EventKind.READ]


def test_write_to_zero_frame_detected():
    """PR 1/2 regression class: a decode scatter routed to the reserved
    zero frame corrupts every unallocated page-table slot."""
    pool = _pool()
    pool.alloc()
    with pytest.raises(AssertionError, match="zero frame"):
        pool.write_rows(np.array([0]), np.array([0]), _rows())
    v = _only(check_page_trace(pool.trace), "write-to-non-hot-frame")
    assert v.event.kind is EventKind.WRITE_ROWS
    assert v.event.frames == (0,)
    assert "zero frame" in v.message


def test_write_to_unowned_frame_detected():
    """The pool's own assert only guards the zero frame; the sanitizer
    catches scatters into ANY frame that backs no hot page."""
    pool = _pool()
    pool.alloc()                                    # occupies one frame
    free = pool.free_frames[0]                      # backs no hot page
    pool.write_rows(np.array([free]), np.array([0]), _rows())  # pool accepts!
    v = _only(check_page_trace(pool.trace), "write-to-non-hot-frame")
    assert v.event.kind is EventKind.WRITE_ROWS
    assert f"frame {free}" in v.message


def test_trash_frame_writes_are_legal():
    pool = _pool()
    pid = pool.alloc()
    frame = int(pool.frames_of([pid])[0])
    pool.write_rows(np.array([1, frame]), np.array([0, 0]), _rows(2))
    assert check_page_trace(pool.trace) == []


def test_double_restore_detected():
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=3, frame=2, refcount=1)
    log.emit(1, EventKind.RESTORE, pid=3, frame=4)      # already hot
    v = _only(check_page_trace(log), "double-restore")
    assert v.pid == 3 and v.event.kind is EventKind.RESTORE
    assert v.event.seq == 1


def test_double_evict_detected():
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=3, frame=2, refcount=1)
    log.emit(1, EventKind.EVICT, pid=3, frame=2)
    log.emit(2, EventKind.EVICT, pid=3, frame=2)        # already cold
    v = _only(check_page_trace(log), "double-evict")
    assert v.pid == 3 and v.event.seq == 2


def test_same_step_churn_detected_from_real_pool():
    """PR 2 regression, reproduced with the REAL pool: an allocation that
    doesn't pin the current working set steals the frame of a page
    restored in the very same clock step."""
    pool = _pool(hot_frames=4)                      # capacity 2
    a = pool.alloc()
    b = pool.alloc(needed=[a])
    pool.note_deadline([a], 100.0)                  # a: most slack
    pool.note_deadline([b], 5.0)                    # b: urgent
    pool.evict(a)                                   # legitimate spill
    pool.ensure_hot([a, b])                         # restores a this step
    # BUG: alloc without needed=[a, b] — the steal victimizes a (latest
    # deadline), whose restore it just paid for, within the same step
    pool.alloc(needed=[b])
    violations = check_page_trace(pool.trace)
    v = _only(violations, "evict-restore-churn")
    assert v.pid == a
    assert v.event.kind is EventKind.EVICT and v.event.cause == "steal"
    kinds = [e.kind for e in v.history]
    assert kinds[-2:] == [EventKind.RESTORE, EventKind.EVICT]
    assert v.history[-1].clock == v.history[-2].clock   # same pool step


def test_correctly_pinned_alloc_produces_no_churn():
    pool = _pool(hot_frames=4)
    a = pool.alloc()
    b = pool.alloc(needed=[a])
    pool.evict(a)
    pool.ensure_hot([a, b])
    with pytest.raises(RuntimeError, match="hot tier exhausted"):
        pool.alloc(needed=[a, b])       # nothing stealable: fails loudly
    assert [v.rule for v in check_page_trace(pool.trace)] == []


def test_deadline_order_violation_detected():
    """A steal that spills the urgent page while a slack page sits hot."""
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=1, frame=2, refcount=1)
    log.emit(0, EventKind.ALLOC, pid=2, frame=3, refcount=1)
    log.emit(0, EventKind.DEADLINE, pid=1, deadline=50.0)
    log.emit(0, EventKind.DEADLINE, pid=2, deadline=10.0)
    log.emit(1, EventKind.EVICT, pid=2, frame=3, cause="steal")   # wrong!
    v = _only(check_page_trace(log), "deadline-order")
    assert v.pid == 2
    assert "page 1" in v.message and "50.0 > 10.0" in v.message


def test_deadline_order_respects_pinned_working_set():
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=1, frame=2, refcount=1)
    log.emit(0, EventKind.ALLOC, pid=2, frame=3, refcount=1)
    log.emit(0, EventKind.DEADLINE, pid=1, deadline=50.0)
    log.emit(0, EventKind.DEADLINE, pid=2, deadline=10.0)
    # pid 1 is pinned (in the step's working set): evicting 2 is correct
    log.emit(1, EventKind.EVICT, pid=2, frame=3, cause="steal", pinned=(1,))
    assert check_page_trace(log) == []


def test_explicit_evictions_exempt_from_victim_order():
    """Policy-driven spills (preemption) may evict any page."""
    pool = _pool()
    a = pool.alloc()
    b = pool.alloc()
    pool.note_deadline([a], 99.0)
    pool.note_deadline([b], 1.0)
    pool.evict(b)                       # urgent page, but explicit: legal
    assert check_page_trace(pool.trace) == []


def test_frame_collision_detected():
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=1, frame=2, refcount=1)
    log.emit(0, EventKind.ALLOC, pid=2, frame=2, refcount=1)    # same frame
    v = _only(check_page_trace(log), "frame-collision")
    assert v.pid == 2 and "already backs hot page 1" in v.message


def test_restore_into_reserved_frame_is_collision():
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=1, frame=2, refcount=1)
    log.emit(0, EventKind.EVICT, pid=1, frame=2)
    log.emit(1, EventKind.RESTORE, pid=1, frame=0)      # the zero frame
    v = _only(check_page_trace(log), "frame-collision")
    assert v.pid == 1 and "reserved frame 0" in v.message


def test_cross_layer_frame_claims_do_not_collide():
    """Per-layer plane identity: with the zero-copy layout a frame index
    names a DIFFERENT buffer row per layer plane, so two pages claiming
    frame 2 in layers 0 and 1 is legal — only a same-(layer, frame) claim
    is a collision. A sanitizer keyed on frame alone would false-positive
    on every fused-sweep serving trace."""
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=1, frame=2, refcount=1, layer=0)
    log.emit(0, EventKind.ALLOC, pid=2, frame=2, refcount=1, layer=1)
    assert check_page_trace(log) == []
    # ...while the SAME plane double-claimed is still a collision
    log.emit(1, EventKind.ALLOC, pid=3, frame=2, refcount=1, layer=1)
    v = _only(check_page_trace(log), "frame-collision")
    assert v.pid == 3 and "already backs hot page 2" in v.message


def test_layer_claim_collides_with_whole_frame_owner():
    """A layer=None claim owns the frame across every plane: a later
    layer-scoped claim of the same frame must still collide."""
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=1, frame=2, refcount=1)    # whole frame
    log.emit(0, EventKind.ALLOC, pid=2, frame=2, refcount=1, layer=3)
    v = _only(check_page_trace(log), "frame-collision")
    assert v.pid == 2


def test_per_layer_write_rows_against_whole_frame_owner_clean():
    """The fused sweep's commit shape: one whole-frame ALLOC, then a
    WRITE_ROWS per layer plane into that frame. Each per-layer write must
    resolve to the whole-frame owner, not flag write-to-non-hot-frame."""
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=1, frame=2, refcount=1)
    for layer in range(3):
        log.emit(1, EventKind.WRITE_ROWS, frames=(2,), layer=layer)
    assert check_page_trace(log) == []


def test_per_layer_write_rows_to_foreign_layer_flagged():
    """A layer-scoped write into a frame owned only by OTHER planes is a
    scatter into unbacked memory and must be flagged with its layer."""
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=1, frame=2, refcount=1, layer=0)
    log.emit(1, EventKind.WRITE_ROWS, frames=(2,), layer=5)
    v = _only(check_page_trace(log), "write-to-non-hot-frame")
    assert "(layer 5)" in v.message


def test_layer_scoped_release_keeps_other_planes_owned():
    """Evicting one plane's claim must not release sibling planes."""
    log = TraceLog()
    log.emit(0, EventKind.ALLOC, pid=1, frame=2, refcount=1, layer=0)
    log.emit(0, EventKind.ALLOC, pid=2, frame=2, refcount=1, layer=1)
    log.emit(1, EventKind.EVICT, pid=1, frame=2)
    log.emit(2, EventKind.WRITE_ROWS, frames=(2,), layer=1)     # still owned
    assert check_page_trace(log) == []
    log.emit(3, EventKind.WRITE_ROWS, frames=(2,), layer=0)     # released
    v = _only(check_page_trace(log), "write-to-non-hot-frame")
    assert "(layer 0)" in v.message


# ======================================================================== #
# incremental (shadow) checking
# ======================================================================== #

def test_feed_log_is_incremental():
    pool = _pool()
    checker = LifecycleChecker()
    a = pool.alloc()
    assert checker.feed_log(pool.trace) == []
    pool.evict(a)
    with pytest.raises(AssertionError):
        pool.frames_of([a])
    fresh = checker.feed_log(pool.trace)
    assert [v.rule for v in fresh] == ["use-after-evict"]
    # already-consumed events are not re-reported
    assert checker.feed_log(pool.trace) == []
    assert len(checker.violations) == 1


def test_lifecycle_violation_error_carries_provenance():
    log = TraceLog()
    log.emit(0, EventKind.UNREF, pid=9, refcount=-1)
    violations = check_page_trace(log)
    err = LifecycleViolationError(violations)
    assert err.violations == violations
    assert "refcount-underflow" in str(err) and "page=9" in str(err)


# ======================================================================== #
# PoolMetrics.validate
# ======================================================================== #

def test_pool_metrics_validate_passes_on_real_pool():
    pool = _pool(hot_frames=4)
    a = pool.alloc()
    pool.alloc(needed=[a])
    pool.evict(a)
    pool.ensure_hot([a])
    pool.metrics.validate()
    assert pool.metrics.page_faults == 1 and pool.metrics.evictions == 1


def test_pool_metrics_validate_rejects_negative_counter():
    m = PoolMetrics()
    m.page_faults = -1
    with pytest.raises(ValueError, match="page_faults is negative"):
        m.validate()


def test_pool_metrics_validate_rejects_unplanned_restore():
    """A restore without a PRELOAD descriptor means the preload plan was
    bypassed — the exact drift PUL exists to prevent."""
    pool = _pool(hot_frames=4)
    a = pool.alloc()
    pool.evict(a)
    pool.ensure_hot([a])
    pool.metrics.descriptors = [
        d for d in pool.metrics.descriptors if d.tag != a or
        d.direction.name != "PRELOAD"]
    with pytest.raises(ValueError, match="restores must be planned"):
        pool.metrics.validate()


def test_pool_metrics_validate_rejects_restore_without_spill():
    m = PoolMetrics()
    m.page_faults = 3
    m.evictions = 1
    with pytest.raises(ValueError, match="PRELOAD descriptors"):
        m.validate()


def test_pool_metrics_latency_hidden_bounds():
    m = PoolMetrics()
    m.modeled_restore_time = 1.0
    m.modeled_restore_stall = 2.0       # stall > total: impossible
    with pytest.raises(ValueError, match="out of"):
        m.validate()


# ======================================================================== #
# DMA-plan verifier
# ======================================================================== #

def _corrupt(cfg: PULConfig, **fields) -> PULConfig:
    """Bypass PULConfig.__post_init__ to build an invalid plan, the way a
    regression (not a user) would."""
    bad = dataclasses.replace(cfg)
    for k, v in fields.items():
        object.__setattr__(bad, k, v)
    return bad


def test_verify_stream_plan_accepts_both_strategies():
    for strat in IssueStrategy:
        cfg = PULConfig(distance=4, strategy=strat)
        report = verify_stream_plan(cfg, n_blocks=32, block_bytes=2048)
        assert report.distance == 4
        assert report.n_blocks == 32
        assert report.max_in_flight >= 1
        assert report.ok


def test_verify_planner_output_end_to_end():
    plan = plan_kv_page_stream(page_tokens=16, kv_features=128,
                               tier=TIERS["remote_hbm"],
                               pe=PES["tpu_v5e_vpu"], gqa_group=4)
    report = verify_kv_page_plan(plan, n_pages=64,
                                 page_bytes=16 * 128 * 2)
    assert report.distance == plan.cfg.distance
    assert report.max_in_flight <= plan.cfg.num_slots


def test_verify_rejects_zero_distance():
    cfg = _corrupt(PULConfig(distance=4), distance=0)
    with pytest.raises(PlanError, match="distance must be >= 1"):
        verify_stream_plan(cfg, n_blocks=8, block_bytes=512)


def test_verify_rejects_distance_beyond_fifo():
    cfg = _corrupt(PULConfig(distance=4, fifo_depth=64), distance=128)
    with pytest.raises(PlanError, match="FIFO"):
        verify_stream_plan(cfg, n_blocks=256, block_bytes=512)


def test_verify_rejects_starved_slot_ring():
    """Slots fewer than the warm-up window: the schedule would overwrite an
    unconsumed slot."""
    cfg = _corrupt(PULConfig(distance=8), slots=2)
    with pytest.raises(PlanError, match="slot"):
        verify_stream_plan(cfg, n_blocks=32, block_bytes=512)


def test_verify_rejects_nonsense_workload():
    cfg = PULConfig(distance=2)
    with pytest.raises(PlanError, match="n_blocks"):
        verify_stream_plan(cfg, n_blocks=-1, block_bytes=512)
    with pytest.raises(PlanError, match="block_bytes"):
        verify_stream_plan(cfg, n_blocks=8, block_bytes=0)


def test_verify_warns_on_fifo_backpressure_without_failing():
    """distance == fifo_depth under BATCH peaks at 2d in-flight; the twin
    models that as back-pressure stall, so it verifies with a warning."""
    cfg = PULConfig(distance=64, fifo_depth=64)
    report = verify_stream_plan(cfg, n_blocks=128, block_bytes=512)
    assert report.warnings and "FIFO" in report.warnings[0]


def test_run_stream_rejects_corrupted_plan_before_execution():
    eng = DMAEngine(TIERS["remote_hbm"], PES["tpu_v5e_vpu"])
    cfg = _corrupt(PULConfig(distance=4), distance=0)
    with pytest.raises(PlanError):
        eng.run_stream(cfg, n_blocks=16, block_bytes=1024,
                       compute_flops_per_block=1024.0)


def test_verify_kv_page_plan_rejects_inconsistent_prediction():
    plan = plan_kv_page_stream(page_tokens=16, kv_features=128,
                               tier=TIERS["remote_hbm"],
                               pe=PES["tpu_v5e_vpu"], gqa_group=4)
    bad = dataclasses.replace(plan, predicted_time_per_block=0.0)
    with pytest.raises(PlanError, match="predicts"):
        verify_kv_page_plan(bad, n_pages=64, page_bytes=16 * 128 * 2)


# ======================================================================== #
# engine shadow mode: the real serving engine, checked every tick
# ======================================================================== #

def test_engine_shadow_check_clean_under_preemption_pressure():
    """The full serving engine with ``shadow_check=True`` replays its own
    page trace through the sanitizer EVERY tick. Preemption forces real
    evict -> cold -> restore traffic, so the checker sees the hard paths
    (steal evictions, swap-out, resume restores) — and stays silent."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import PagedEngineConfig, PagedServingEngine, Request

    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(dataclasses.replace(cfg, paged_kv=True))
    params = model.init(jax.random.PRNGKey(0))
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8,
        prefill_buckets=(8, 16, 32), policy="priority", shadow_check=True))
    assert eng.pool.trace is not None

    def prompt(seed, n):
        return np.random.default_rng(seed).integers(
            1, cfg.vocab_size, size=n).tolist()

    eng.submit(Request(rid=0, prompt=prompt(0, 9), max_new_tokens=12,
                       priority=0))
    eng.submit(Request(rid=1, prompt=prompt(1, 7), max_new_tokens=12,
                       priority=0))
    for _ in range(3):
        eng.step()
    eng.submit(Request(rid=2, prompt=prompt(2, 5), max_new_tokens=4,
                       priority=5))
    eng.run()                           # raises LifecycleViolationError on
                                        # any contract break, at the tick
    assert eng.metrics.preemptions >= 1
    assert eng.pool.metrics.page_faults >= 1
    assert len(eng.pool.trace) > 0
    assert eng._shadow_checker.violations == []


def test_engine_shadow_check_raises_on_injected_corruption():
    """Poisoning the trace makes the NEXT tick fail — the shadow checker
    is live, not decorative."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import PagedEngineConfig, PagedServingEngine, Request

    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(dataclasses.replace(cfg, paged_kv=True))
    params = model.init(jax.random.PRNGKey(0))
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=1, max_seq=64, page_tokens=8, prefill_buckets=(8, 16),
        shadow_check=True))
    eng.submit(Request(
        rid=0,
        prompt=np.random.default_rng(0).integers(
            1, cfg.vocab_size, size=6).tolist(),
        max_new_tokens=8))
    eng.step()
    eng.pool.trace.emit(0, EventKind.UNREF, pid=999, refcount=-1)
    with pytest.raises(LifecycleViolationError, match="refcount-underflow"):
        eng.step()

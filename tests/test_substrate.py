"""Substrate: sharding resolver, optimizer, compression, data, checkpoint,
fault handling, serving engine."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import CONFIGS, get_config
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model, demo_batch
from repro.models import module as M
from repro.optim import OptimizerConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim import compression as C
from repro.runtime.fault import HeartbeatMonitor, rescale_plan
from repro.runtime.sharding import ShardingRules, logical_to_spec
from repro.serving import EngineConfig, Request, ServingEngine


# ---------------------------------------------------------------- sharding
class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH_SINGLE = _FakeMesh({"data": 16, "model": 16})
MESH_MULTI = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_resolver_basic_2d_weight():
    spec = logical_to_spec(("embed", "ff"), (4096, 16384), MESH_MULTI)
    assert spec == P(("pod", "data"), "model")


def test_resolver_divisibility_fallback():
    # 40 heads don't divide the 16-way model axis -> replicated
    spec = logical_to_spec(("embed", "heads", "head_dim"), (5120, 40, 128),
                           MESH_MULTI)
    assert spec == P(("pod", "data"))
    # 48 heads do
    spec = logical_to_spec(("embed", "heads", "head_dim"), (6144, 48, 128),
                           MESH_MULTI)
    assert spec == P(("pod", "data"), "model")


def test_resolver_no_axis_reuse():
    # batch takes (pod,data); cache_seq then falls to model
    spec = logical_to_spec(("cache_batch", "cache_seq", "act_kv_heads", None),
                           (128, 32768, 8, 128), MESH_MULTI)
    assert spec == P(("pod", "data"), "model")


def test_resolver_single_pod_mesh_skips_pod_axis():
    spec = logical_to_spec(("embed", "ff"), (4096, 16384), MESH_SINGLE)
    assert spec == P("data", "model")


def test_resolver_every_param_of_every_arch(subtests=None):
    """No Param in the zoo fails to resolve on either mesh."""
    for mesh in (MESH_SINGLE, MESH_MULTI):
        for arch, cfg in CONFIGS.items():
            tree = build_model(cfg).params
            specs = M.param_specs(tree, mesh)      # raises on failure
            assert len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))) > 0


# --------------------------------------------------------------- optimizer
def test_adamw_matches_reference_step():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16), "b": jnp.zeros((4,), jnp.float32)}
    grads = {"w": jnp.full((4, 4), 0.5, jnp.float32), "b": jnp.ones((4,), jnp.float32)}
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                          weight_decay=0.0, clip_norm=1e9)
    state = adamw_init(params)
    new_p, new_s, metrics = adamw_update(grads, state, params, cfg)
    # step 1: mhat = g, vhat = g^2 -> delta = g/|g| = 1
    lr1 = float(cosine_schedule(cfg, jnp.int32(1)))
    np.testing.assert_allclose(np.asarray(new_p["b"]),
                               -lr1 * np.ones(4), rtol=1e-4)
    assert int(metrics["step"]) == 1


def test_grad_clipping():
    params = {"w": jnp.zeros((8,), jnp.float32)}
    big = {"w": jnp.full((8,), 100.0)}
    cfg = OptimizerConfig(lr=1.0, clip_norm=1.0, warmup_steps=0,
                          weight_decay=0.0)
    state = adamw_init(params)
    _, _, metrics = adamw_update(big, state, params, cfg)
    assert float(metrics["grad_norm"]) > 100


# -------------------------------------------------------------- compression
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 1000))
def test_quantize_roundtrip_bounded(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, s = C.quantize_int8(x)
    err = np.abs(np.asarray(C.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback, the *cumulative* quantized sum tracks the true
    cumulative sum much better than independent quantization."""
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 0.01
    err = jnp.zeros_like(g)
    acc_ef, acc_naive = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(50):
        q, s, err = C.ef_quantize(g, err)
        acc_ef += C.dequantize_int8(q, s)
        qn, sn = C.quantize_int8(g)
        acc_naive += C.dequantize_int8(qn, sn)
    true = g * 50
    assert (jnp.linalg.norm(acc_ef - true)
            <= jnp.linalg.norm(acc_naive - true) + 1e-5)


# --------------------------------------------------------------------- data
def test_data_deterministic_and_resumable():
    cfg = DataConfig(global_batch=4, seq_len=32, vocab_size=1000, seed=7)
    p1 = TokenPipeline(cfg)
    batches = [next(p1) for _ in range(5)]
    p2 = TokenPipeline(cfg)
    p2.skip_to(3)
    b3 = next(p2)
    np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                  np.asarray(b3["tokens"]))


def test_data_prefetch_thread_matches_sync():
    cfg = DataConfig(global_batch=2, seq_len=16, vocab_size=100, seed=1,
                     prefetch_distance=3)
    sync = TokenPipeline(cfg)
    want = [np.asarray(next(sync)["tokens"]) for _ in range(4)]
    pre = TokenPipeline(cfg)
    pre.start()
    got = [np.asarray(next(pre)["tokens"]) for _ in range(4)]
    pre.stop()
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


def test_data_targets_are_shifted_tokens():
    cfg = DataConfig(global_batch=2, seq_len=16, vocab_size=50, seed=3)
    b = next(TokenPipeline(cfg))
    # targets[t] == token stream at t+1 (teacher forcing) — checked via
    # overlap: tokens[1:] == targets[:-1]
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(CheckpointConfig(d, keep=2))
        state = {"w": jnp.arange(8, dtype=jnp.float32),
                 "n": {"v": jnp.ones((2, 2), jnp.bfloat16)}}
        for s in (10, 20, 30):
            mgr.save(s, jax.tree.map(lambda x: x * s, state))
        mgr.wait()
        assert mgr.latest_step() == 30
        step, restored = mgr.restore(like=state)
        assert step == 30
        np.testing.assert_allclose(np.asarray(restored["w"], np.float32),
                                   np.arange(8) * 30)
        # keep=2 garbage-collected step 10
        assert mgr._steps() == [20, 30]


def test_checkpoint_restart_continuation():
    """Kill-and-restart yields the same state as an uninterrupted run."""
    cfg = get_config("qwen3-1.7b").reduced()
    m = build_model(cfg)
    from repro.launch.steps import make_train_step
    step_fn = jax.jit(make_train_step(cfg, OptimizerConfig(lr=1e-3)))
    dcfg = DataConfig(global_batch=2, seq_len=16, vocab_size=cfg.vocab_size,
                      seed=5)

    def run(n_steps, params, opt, start=0):
        data = TokenPipeline(dcfg)
        data.skip_to(start)
        for _ in range(start, n_steps):
            params, opt, _ = step_fn(params, opt, next(data))
        return params, opt

    p0 = m.init(jax.random.PRNGKey(0))
    o0 = adamw_init(p0)
    p_full, o_full = run(4, p0, o0)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(CheckpointConfig(d))
        p2, o2 = run(2, p0, o0)
        mgr.save(2, (p2, o2), block=True)
        step, (p2r, o2r) = mgr.restore(like=(p2, o2))
        p_resumed, _ = run(4, p2r, o2r, start=step)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


# -------------------------------------------------------------------- fault
def test_heartbeat_dead_worker_detection():
    hb = HeartbeatMonitor(deadline_s=10)
    hb.beat("a", now=0.0)
    hb.beat("b", now=0.0)
    hb.beat("a", now=8.0)
    assert hb.dead_workers(now=12.0) == ["b"]


def test_straggler_detection():
    hb = HeartbeatMonitor()
    for i in range(16):
        for w in ("a", "b", "c", "d"):
            hb.beat(w, step_time=1.0 + (3.0 if w == "c" else 0.0))
    assert hb.stragglers() == ["c"]


def test_rescale_plan():
    plan = rescale_plan(2, 1)
    assert plan.new_mesh == (16, 16)
    assert plan.batch_scale == 2.0
    plan = rescale_plan(1, 2)
    assert plan.new_mesh == (2, 16, 16)
    with pytest.raises(ValueError):
        rescale_plan(2, 0)


# ------------------------------------------------------------------ serving
def test_serving_engine_matches_manual_decode():
    cfg = get_config("qwen3-1.7b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(batch_slots=2, max_seq=64,
                                                  prefill_bucket=16))
    prompt = [5, 7, 11, 13]
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    out = eng.run()[0]
    assert len(out) == 4

    # manual greedy decode with left-padded prompt (same as engine's bucket)
    bucket = 16
    toks = np.zeros((2, bucket), np.int32)
    toks[0, -len(prompt):] = prompt
    logits, caches = jax.jit(lambda p, b: m.prefill(p, b, max_seq=64))(
        params, {"tokens": jnp.asarray(toks)})
    manual = [int(np.argmax(np.asarray(logits)[0]))]
    pos = bucket
    for _ in range(3):
        step = np.zeros((2, 1), np.int32)
        step[0, 0] = manual[-1]
        logits, caches = jax.jit(m.decode_step)(
            params, {"tokens": jnp.asarray(step),
                     "pos0": jnp.full((2,), pos, jnp.int32)}, caches)
        manual.append(int(np.argmax(np.asarray(logits)[0])))
        pos += 1
    assert out == manual

"""Test bootstrap: make `src/` importable and shim hypothesis if absent.

Tier-1 runs as ``PYTHONPATH=src python -m pytest -x -q``; the sys.path insert
below keeps plain ``pytest`` working too. The hypothesis shim keeps the
property tests runnable on minimal CPU environments (the container image does
not ship hypothesis); when the real library is installed it wins.
"""
import os
import sys

# Pin CPU-backend threading BEFORE jax is imported: multi-threaded reduction
# partitioning can reorder float accumulation run-to-run, and the reduced
# zoo models' bf16 logits carry 1-ulp near-ties that turn such reordering
# into rare token-stream flips in the differential suites (observed roughly
# once per several full runs; any token-comparison test could be hit).
os.environ.setdefault("OMP_NUM_THREADS", "1")
if "--xla_cpu_multi_thread_eigen" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_cpu_multi_thread_eigen=false").strip()

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypothesis_compat import install

    install(sys.modules)

"""SLO-aware scheduling: policies, preemption, chunked prefill, and the
admission-accounting regression sweep.

Three families:

  * scheduler/pool unit tests (no model): the admission-accounting bugfixes
    (true-length request_cost, never-admittable head detection, degenerate
    metrics guards), policy ordering, requeue position, deadline-aware
    eviction;
  * engine differentials (reduced zoo models): preempt -> swap-out ->
    resume mid-decode stays token-identical to the dense reference, across
    a dense arch (qwen3) and MLA (deepseek), including preemption while a
    chunked prefill is in flight;
  * chunked-prefill liveness: a long prompt never stalls short requests'
    decode ticks, and every token stream still matches the dense reference.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    AdmissionScheduler,
    EngineMetrics,
    KVPagePool,
    PageConfig,
    PagedEngineConfig,
    PagedServingEngine,
    Request,
    SchedulerConfig,
    mean,
    percentile,
)

pytestmark = pytest.mark.paged

_MODELS = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = get_config(arch).reduced()
        m = build_model(dataclasses.replace(cfg, paged_kv=True))
        params = m.init(jax.random.PRNGKey(0))
        _MODELS[arch] = (cfg, m, params)
    return _MODELS[arch]


def _set_idx(tree, vec):
    flat, td = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = tuple(getattr(p, "key", str(p)) for p in path)
        if keys[-1] == "idx":
            leaf = jnp.broadcast_to(jnp.asarray(vec, jnp.int32), leaf.shape)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(td, out)


def _pick_bucket(buckets, n, max_seq=64):
    for b in buckets:
        if n <= b:
            return b
    return max(max_seq, buckets[-1])


def dense_reference(model, params, prompt, max_new, bucket, *, B, max_seq):
    """Per-request greedy decode over a monolithic dense cache (same
    compiled shapes as the engine, so token streams must match exactly)."""
    prompt = prompt[-bucket:]
    toks = np.zeros((B, bucket), np.int32)
    toks[0, :len(prompt)] = prompt
    lengths = np.ones((B,), np.int32)
    lengths[0] = len(prompt)
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, max_seq=max_seq))(
        params, {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lengths)})
    out = [int(np.argmax(np.asarray(logits)[0]))]
    pos = np.zeros((B,), np.int32)
    pos[0] = len(prompt)
    caches = _set_idx(caches, pos)
    dec = jax.jit(model.decode_step)
    for _ in range(max_new - 1):
        step = np.zeros((B, 1), np.int32)
        step[0, 0] = out[-1]
        logits, caches = dec(params, {"tokens": jnp.asarray(step),
                                      "pos0": jnp.asarray(pos)}, caches)
        pos = pos + 1
        caches = _set_idx(caches, pos)
        out.append(int(np.argmax(np.asarray(logits)[0])))
    return out


def _rand_prompt(seed, n, vocab):
    return np.random.default_rng(seed).integers(1, vocab, size=n).tolist()


# ========================================================================== #
# admission-accounting regression sweep (scheduler-only, no model)
# ========================================================================== #
def test_request_cost_charges_true_prompt_length():
    """Regression: a prompt longer than the largest prefill bucket must be
    charged at its TRUE length, not capped at the bucket — the pre-fix
    ``min(len(prompt), bucket)`` under-counted both the token budget and
    the page demand for exactly the requests served through the implicit
    max_seq top bucket."""
    sched = AdmissionScheduler(SchedulerConfig(
        prefill_buckets=(8, 16), page_tokens=8, max_seq=64))
    req = Request(rid=0, prompt=list(range(40)), max_new_tokens=10)
    assert sched.request_cost(req) == 50          # pre-fix: 16 + 10 = 26
    assert sched.request_pages(req) == 7          # ceil(50/8); pre-fix: 4
    # within-bucket requests are charged exactly as before
    short = Request(rid=1, prompt=list(range(5)), max_new_tokens=10)
    assert sched.request_cost(short) == 15


def test_pick_bucket_implicit_top_never_truncates():
    sched = AdmissionScheduler(SchedulerConfig(
        prefill_buckets=(8, 16), page_tokens=8, max_seq=64))
    assert sched.pick_bucket(5) == 8
    assert sched.pick_bucket(16) == 16
    assert sched.pick_bucket(40) == 64            # pre-fix: 16 (truncating)


def test_submit_rejects_request_that_can_never_fit():
    """Regression: prompt + max_new_tokens beyond max_seq is rejected at
    submit instead of being silently truncated into the largest bucket."""
    cfg, model, params = _model("qwen3-1.7b")
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8,
        prefill_buckets=(8, 16, 32)))
    with pytest.raises(ValueError, match="never fit"):
        eng.submit(Request(rid=0, prompt=_rand_prompt(0, 60, cfg.vocab_size),
                           max_new_tokens=10))
    # a long-but-feasible prompt (above the largest bucket, within max_seq)
    # is accepted
    eng.submit(Request(rid=1, prompt=_rand_prompt(1, 40, cfg.vocab_size),
                       max_new_tokens=10))


def test_never_admittable_head_fails_instead_of_starving():
    """Regression: a head-of-queue request whose page demand exceeds the
    pool's TOTAL hot frames used to block admission forever, starving every
    feasible request behind it. It must fail visibly and let the queue
    drain."""
    sched = AdmissionScheduler(SchedulerConfig(
        prefill_buckets=(8, 16), page_tokens=8, max_active_tokens=200,
        max_seq=128))
    impossible = Request(rid=0, prompt=list(range(80)), max_new_tokens=8)
    feasible = Request(rid=1, prompt=list(range(8)), max_new_tokens=8)
    sched.submit(impossible, now=0)
    sched.submit(feasible, now=0)
    out = sched.admit([0, 1], active_tokens=0, free_hot_frames=10, now=0,
                      total_hot_frames=10)
    # pre-fix: out == [] every call, rid 1 starves behind rid 0
    assert [a.request.rid for a in out] == [1]
    assert impossible.failed and impossible.done
    assert "pages" in impossible.error
    assert sched.rejected == 1 and sched.failed == [impossible]
    assert len(sched) == 0


def test_head_over_whole_token_budget_fails_visibly():
    sched = AdmissionScheduler(SchedulerConfig(
        prefill_buckets=(8, 16), page_tokens=8, max_active_tokens=20,
        max_seq=128))
    too_big = Request(rid=0, prompt=list(range(22)), max_new_tokens=8)
    ok = Request(rid=1, prompt=list(range(4)), max_new_tokens=8)
    sched.submit(too_big, now=0)
    sched.submit(ok, now=0)
    out = sched.admit([0], active_tokens=0, free_hot_frames=50, now=0,
                      total_hot_frames=50)
    assert [a.request.rid for a in out] == [1]
    assert too_big.failed and "budget" in too_big.error


def test_temporarily_blocked_head_still_blocks_fcfs():
    """The starvation fix must NOT turn head-blocking off: a head that fits
    the pool but not the CURRENT budget keeps waiting (and keeps blocking),
    because time can make it feasible."""
    sched = AdmissionScheduler(SchedulerConfig(
        prefill_buckets=(8, 16), page_tokens=8, max_active_tokens=40,
        max_seq=128))
    head = Request(rid=0, prompt=list(range(16)), max_new_tokens=8)
    later = Request(rid=1, prompt=list(range(4)), max_new_tokens=4)
    sched.submit(head, now=0)
    sched.submit(later, now=0)
    out = sched.admit([0], active_tokens=30, free_hot_frames=50, now=0,
                      total_hot_frames=50)
    assert out == [] and len(sched) == 2 and not head.failed


def test_metrics_degenerate_inputs():
    """Regression: zero-duration / empty-sample metrics must yield clean
    zeros, not ZeroDivisionError / nan — tiny benchmark configs snapshot
    before any work has happened."""
    m = EngineMetrics()
    assert m.tokens_per_sec == 0.0
    m.tokens_emitted = 5
    m.wall_time = 0.0
    assert m.tokens_per_sec == 0.0                # pre-fix: ZeroDivisionError
    m.wall_time = 2.0
    assert m.tokens_per_sec == 2.5
    assert percentile([], 99) == 0.0              # pre-fix: np raises / nan
    assert mean([]) == 0.0                        # pre-fix: nan + warning
    assert percentile([3.0], 50) == 3.0
    assert percentile([0, 10], 50) == 5.0
    assert mean([1, 2, 3]) == 2.0


# ========================================================================== #
# policy ordering + requeue semantics (scheduler-only)
# ========================================================================== #
def test_priority_policy_orders_queue():
    sched = AdmissionScheduler(SchedulerConfig(
        prefill_buckets=(8,), page_tokens=8, policy="priority", max_seq=64))
    lo = Request(rid=0, prompt=[1], max_new_tokens=2, priority=0)
    hi = Request(rid=1, prompt=[2], max_new_tokens=2, priority=5)
    mid = Request(rid=2, prompt=[3], max_new_tokens=2, priority=3)
    for r in (lo, hi, mid):
        sched.submit(r, now=0)
    assert sched.head() is hi
    assert [r.rid for r in sched.queue] == [1, 2, 0]


def test_slo_edf_policy_orders_by_deadline():
    sched = AdmissionScheduler(SchedulerConfig(
        prefill_buckets=(8,), page_tokens=8, policy="slo-edf", max_seq=64))
    slack = Request(rid=0, prompt=[1], max_new_tokens=2, ttft_deadline=9)
    tight = Request(rid=1, prompt=[2], max_new_tokens=2, ttft_deadline=3)
    none = Request(rid=2, prompt=[3], max_new_tokens=2)       # no deadline
    for r in (slack, tight, none):
        sched.submit(r, now=0)
    assert sched.head() is tight
    assert [r.rid for r in sched.queue] == [1, 0, 2]
    # a deadline stops mattering once the first token is out: the request
    # must not preempt its way back after being served
    tight.first_token_tick = 1
    assert tight.deadline_tick() == math.inf
    assert sched.head() is slack


def test_fcfs_requeue_restores_arrival_position():
    """A preempted request readmits at its ORIGINAL arrival position, not
    the back of the queue — preemption must not double-penalize."""
    sched = AdmissionScheduler(SchedulerConfig(
        prefill_buckets=(8,), page_tokens=8, max_seq=64))
    first = Request(rid=0, prompt=[1], max_new_tokens=4)
    second = Request(rid=1, prompt=[2], max_new_tokens=4)
    sched.submit(first, now=0)
    sched.submit(second, now=0)
    out = sched.admit([0], active_tokens=0, free_hot_frames=8, now=0,
                      total_hot_frames=8)
    assert [a.request.rid for a in out] == [0]
    sched.requeue(first, now=3)
    assert [r.rid for r in sched.queue] == [0, 1]
    assert first.resuming and first.preemptions == 1
    # readmission must not record a second queue-latency sample
    n = len(sched.queue_latencies())
    sched.admit([0], active_tokens=0, free_hot_frames=8, now=5,
                total_hot_frames=8)
    assert len(sched.queue_latencies()) == n


def test_scheduler_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        SchedulerConfig(prefill_buckets=(8,), policy="sjf")


# ========================================================================== #
# deadline-aware eviction (pool-only)
# ========================================================================== #
def test_eviction_prefers_latest_deadline_then_lru():
    pool = KVPagePool(PageConfig(page_tokens=8, hot_frames=4), features=4)
    assert pool.capacity == 2
    tight = pool.alloc()
    slack = pool.alloc()
    pool.note_deadline([tight], 5.0)
    pool.note_deadline([slack], 50.0)
    pool.alloc()                     # needs a frame: someone must go cold
    assert pool.pages[slack].frame is None and slack in pool.cold
    assert pool.pages[tight].frame is not None

    # tie on deadline -> LRU (the original ordering) decides
    pool2 = KVPagePool(PageConfig(page_tokens=8, hot_frames=4), features=4)
    old = pool2.alloc()
    pool2.tick()
    young = pool2.alloc()
    pool2.note_deadline([old, young], 7.0)
    pool2.alloc()
    assert pool2.pages[old].frame is None
    assert pool2.pages[young].frame is not None


# ========================================================================== #
# engine differentials: preempt -> swap-out -> resume, chunked prefill
# ========================================================================== #
def test_priority_preemption_resumes_token_identical():
    """Two low-priority decoders fill both slots; a high-priority arrival
    preempts one (swap-out to the cold tier), runs to completion, and the
    victim resumes mid-decode — every stream matches the dense reference
    token-for-token."""
    cfg, model, params = _model("qwen3-1.7b")
    buckets = (8, 16, 32)
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=buckets,
        policy="priority"))
    specs = [(0, 9, 12, 0), (1, 7, 12, 0)]        # (rid, plen, new, prio)
    for rid, plen, new, prio in specs:
        eng.submit(Request(rid=rid, prompt=_rand_prompt(rid, plen,
                                                        cfg.vocab_size),
                           max_new_tokens=new, priority=prio))
    for _ in range(3):
        eng.step()
    specs.append((2, 5, 4, 5))
    eng.submit(Request(rid=2, prompt=_rand_prompt(2, 5, cfg.vocab_size),
                       max_new_tokens=4, priority=5))
    got = eng.run()
    assert eng.metrics.preemptions >= 1
    assert eng.metrics.readmissions >= 1
    assert eng.pool.metrics.page_faults >= 1      # resume restored from cold
    for rid, plen, new, _ in specs:
        want = dense_reference(model, params,
                               _rand_prompt(rid, plen, cfg.vocab_size), new,
                               _pick_bucket(buckets, plen), B=2, max_seq=64)
        assert got[rid] == want, f"rid {rid}: {got[rid]} != {want}"


def test_priority_preemption_mla_single_slot():
    """MLA (deepseek): preempt/resume over compressed-KV pages with a
    single slot (MoE capacity dispatch is batch-composition-sensitive, so
    the comparison keeps exactly one live request at all times)."""
    cfg, model, params = _model("deepseek-v2-236b")
    buckets = (8, 16, 32)
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=1, max_seq=64, page_tokens=8, prefill_buckets=buckets,
        policy="priority", use_paged_kernel=True))
    low = _rand_prompt(0, 13, cfg.vocab_size)
    eng.submit(Request(rid=0, prompt=list(low), max_new_tokens=10,
                       priority=0))
    for _ in range(3):
        eng.step()
    hi = _rand_prompt(1, 5, cfg.vocab_size)
    eng.submit(Request(rid=1, prompt=list(hi), max_new_tokens=4, priority=1))
    got = eng.run()
    assert eng.metrics.preemptions == 1
    assert eng.metrics.readmissions == 1
    for rid, p, new in ((0, low, 10), (1, hi, 4)):
        want = dense_reference(model, params, p, new,
                               _pick_bucket(buckets, len(p)),
                               B=1, max_seq=64)
        assert got[rid] == want, f"rid {rid}: {got[rid]} != {want}"


def test_preemption_during_chunked_prefill():
    """Preempting a slot whose chunked prefill is still in flight must
    snapshot the chunk progress, swap out the banked pages, and resume the
    ladder exactly where it stopped — first token and the whole stream stay
    dense-reference-exact."""
    cfg, model, params = _model("qwen3-1.7b")
    buckets = (8, 16, 32)
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=1, max_seq=64, page_tokens=8, prefill_buckets=buckets,
        policy="priority", prefill_chunk_tokens=8))
    long = _rand_prompt(10, 20, cfg.vocab_size)
    eng.submit(Request(rid=0, prompt=list(long), max_new_tokens=6,
                       priority=0))
    eng.step()                                    # one chunk pass banked
    assert 0 in eng._chunk and eng._chunk[0]["filled"] == 8
    hi = _rand_prompt(11, 4, cfg.vocab_size)
    eng.submit(Request(rid=1, prompt=list(hi), max_new_tokens=3, priority=2))
    got = eng.run()
    assert eng.metrics.preemptions == 1
    assert eng.metrics.readmissions == 1
    assert eng.metrics.chunk_passes == 3          # 8 + 8 + 4, no redo pass
    for rid, p, new in ((0, long, 6), (1, hi, 3)):
        want = dense_reference(model, params, p, new,
                               _pick_bucket(buckets, len(p)),
                               B=1, max_seq=64)
        assert got[rid] == want, f"rid {rid}: {got[rid]} != {want}"


def test_chunked_prefill_never_stalls_decode():
    """Acceptance: one long multi-page prompt chunk-prefills while three
    short requests stream through the other slot — every tick with the
    chunk in flight still emits decode tokens (no skipped decode tick), and
    all four streams match the dense reference."""
    cfg, model, params = _model("qwen3-1.7b")
    buckets = (8, 16, 32)
    eng = PagedServingEngine(cfg, params, PagedEngineConfig(
        batch_slots=2, max_seq=64, page_tokens=8, prefill_buckets=buckets,
        prefill_chunk_tokens=8))
    long = _rand_prompt(20, 40, cfg.vocab_size)
    eng.submit(Request(rid=0, prompt=list(long), max_new_tokens=4))
    shorts = {rid: _rand_prompt(20 + rid, 5, cfg.vocab_size)
              for rid in (1, 2, 3)}
    for rid, p in shorts.items():
        eng.submit(Request(rid=rid, prompt=list(p), max_new_tokens=6))
    pending = lambda: (len(eng.scheduler)
                       or any(r is not None for r in eng.slot_req))
    chunk_ticks = 0
    while pending():
        chunking = bool(eng._chunk)
        before = eng.metrics.tokens_emitted
        eng.step()
        if chunking:
            chunk_ticks += 1
            assert eng.metrics.tokens_emitted > before, \
                f"decode stalled at tick {eng._tick} during chunked prefill"
    got = eng.run()                               # drains completed requests
    assert eng.metrics.chunk_passes == 5          # ceil(40 / 8)
    assert chunk_ticks >= 4                       # passes after the first
    want_long = dense_reference(model, params, long, 4,
                                _pick_bucket(buckets, 40), B=2, max_seq=64)
    assert got[0] == want_long
    for rid, p in shorts.items():
        want = dense_reference(model, params, p, 6,
                               _pick_bucket(buckets, len(p)),
                               B=2, max_seq=64)
        assert got[rid] == want, f"rid {rid}: {got[rid]} != {want}"


def test_slo_edf_preempts_only_when_deadline_at_risk():
    """slo-edf with slack does nothing (no preemption churn); with a
    deadline that cannot be met by waiting it preempts, meets the SLO, and
    the victim resumes token-identically."""
    cfg, model, params = _model("qwen3-1.7b")
    buckets = (8, 16, 32)

    def build():
        return PagedServingEngine(cfg, params, PagedEngineConfig(
            batch_slots=1, max_seq=64, page_tokens=8,
            prefill_buckets=buckets, policy="slo-edf"))

    # (a) generous deadline: waiting meets it, so no preemption happens
    eng = build()
    eng.submit(Request(rid=0, prompt=_rand_prompt(30, 6, cfg.vocab_size),
                       max_new_tokens=4))
    eng.step()
    slack_req = Request(rid=1, prompt=_rand_prompt(31, 4, cfg.vocab_size),
                        max_new_tokens=2, ttft_deadline=10)
    eng.submit(slack_req)
    eng.run()
    assert eng.metrics.preemptions == 0
    assert eng.metrics.slo_violations == 0
    assert 0 <= slack_req.ttft <= 10

    # (b) tight deadline: the running request won't finish in time ->
    # preempt, serve, resume; zero violations and exact resumed stream
    eng = build()
    low = _rand_prompt(32, 6, cfg.vocab_size)
    eng.submit(Request(rid=0, prompt=list(low), max_new_tokens=20))
    eng.step()
    eng.step()
    hi = _rand_prompt(33, 4, cfg.vocab_size)
    req_hi = Request(rid=1, prompt=list(hi), max_new_tokens=2,
                     ttft_deadline=4)
    eng.submit(req_hi)
    got = eng.run()
    assert eng.metrics.preemptions == 1
    assert eng.metrics.readmissions == 1
    assert eng.metrics.slo_violations == 0
    assert 0 <= req_hi.ttft <= 4
    for rid, p, new in ((0, low, 20), (1, hi, 2)):
        want = dense_reference(model, params, p, new,
                               _pick_bucket(buckets, len(p)),
                               B=1, max_seq=64)
        assert got[rid] == want, f"rid {rid}: {got[rid]} != {want}"

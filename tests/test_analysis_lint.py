"""jit-safety lint: one fixture per rule, plus waivers and the clean-tree
gate (``src/repro`` must lint clean — the same check CI runs)."""
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import RULES, lint_paths, lint_source

pytestmark = pytest.mark.analysis


def _lint(code):
    return lint_source(textwrap.dedent(code), "fixture.py")


def _rules(code):
    return [f.rule for f in _lint(code)]


# ======================================================================== #
# PUL101: Python control flow on traced values
# ======================================================================== #

def test_traced_branch_in_jitted_function_flagged():
    findings = _lint("""
        import jax

        @jax.jit
        def step(x):
            if x > 0:           # trace-time branch on a traced value
                return x
            return -x
    """)
    assert [f.rule for f in findings] == ["PUL101"]
    assert findings[0].line == 6
    assert "x" in findings[0].message


def test_traced_while_via_annotation_flagged_outside_jit():
    assert _rules("""
        import jax

        def host_fn(x: jax.Array):
            while x.sum() > 0:
                x = x - 1
    """) == ["PUL101"]


def test_branch_on_static_shape_is_clean():
    assert _rules("""
        import jax

        @jax.jit
        def step(x):
            if x.shape[0] > 8:      # shapes are static under tracing
                return x[:8]
            if x is None or len(x.shape) == 1:
                return x
            return x
    """) == []


def test_branch_on_host_annotated_value_is_clean():
    assert _rules("""
        import jax

        @jax.jit
        def step(x, n: int):
            if n > 4:               # n is a static/python argument
                return x * n
            return x
    """) == []


def test_traced_propagates_through_assignment():
    assert _rules("""
        import jax.numpy as jnp

        def f_kernel(x_ref, o_ref):
            y = x_ref[...] * 2
            if y[0] > 0:
                o_ref[...] = y
    """) == ["PUL101"]


def test_pallas_call_argument_is_a_jit_context():
    assert _rules("""
        import functools
        from jax.experimental import pallas as pl

        def _body(x_ref, o_ref):
            if x_ref[0]:
                o_ref[...] = x_ref[...]

        def run(x):
            kern = functools.partial(_body)
            return pl.pallas_call(kern, out_shape=x)(x)
    """) == ["PUL101"]


# ======================================================================== #
# PUL102: host syncs
# ======================================================================== #

def test_item_in_jit_flagged():
    assert _rules("""
        import jax

        @jax.jit
        def step(x):
            return x.sum().item()
    """) == ["PUL102"]


def test_float_cast_of_traced_flagged():
    assert _rules("""
        import jax

        @jax.jit
        def step(x):
            return float(x[0])
    """) == ["PUL102"]


def test_np_asarray_of_traced_flagged():
    assert _rules("""
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            return np.asarray(x)
    """) == ["PUL102"]


def test_host_sync_outside_jit_is_clean():
    assert _rules("""
        import numpy as np

        def report(x):
            return float(np.asarray(x).mean())
    """) == []


# ======================================================================== #
# PUL103: non-static BlockSpec shapes
# ======================================================================== #

def test_traced_blockspec_shape_flagged():
    assert _rules("""
        import jax
        from jax.experimental import pallas as pl

        def build(n: jax.Array):
            return pl.BlockSpec((n, 128), lambda i: (i, 0))
    """) == ["PUL103"]


def test_static_blockspec_is_clean():
    assert _rules("""
        from jax.experimental import pallas as pl

        def build(rows: int):
            return pl.BlockSpec((rows, 128), lambda i: (i, 0))
    """) == []


def test_memory_space_only_blockspec_is_clean():
    """The repo's kernels build BlockSpecs with only memory_space kwargs."""
    assert _rules("""
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        def specs():
            return [pl.BlockSpec(memory_space=pltpu.SMEM),
                    pl.BlockSpec(memory_space=pl.ANY)]
    """) == []


# ======================================================================== #
# PUL104: mutable defaults
# ======================================================================== #

def test_mutable_default_flagged():
    findings = _lint("""
        def admit(reqs=[]):
            return reqs
    """)
    assert [f.rule for f in findings] == ["PUL104"]
    assert "admit" in findings[0].message


def test_none_default_is_clean():
    assert _rules("""
        def admit(reqs=None, cfg=(), tag=""):
            return reqs or []
    """) == []


# ======================================================================== #
# PUL105: swallowed exceptions
# ======================================================================== #

def test_bare_except_flagged():
    assert _rules("""
        def f():
            try:
                g()
            except:
                pass
    """) == ["PUL105"]


def test_base_exception_without_reraise_flagged():
    assert _rules("""
        def f():
            try:
                g()
            except BaseException:
                cleanup()
    """) == ["PUL105"]


def test_base_exception_with_reraise_is_clean():
    assert _rules("""
        def f():
            try:
                g()
            except BaseException:
                cleanup()
                raise
    """) == []


def test_silent_exception_swallow_flagged():
    """The dryrun.py regression shape: except Exception whose handler
    neither re-raises nor looks at the exception."""
    assert _rules("""
        def sweep():
            try:
                run()
            except Exception:
                results = "error"
    """) == ["PUL105"]


def test_logged_exception_is_clean():
    assert _rules("""
        import traceback

        def sweep():
            try:
                run()
            except Exception as e:
                traceback.print_exc()
                print(f"swallowed {type(e).__name__}")
    """) == []


def test_narrow_except_is_clean():
    assert _rules("""
        def f():
            try:
                g()
            except (KeyError, ValueError):
                pass
    """) == []


# ======================================================================== #
# PUL106: unbalanced tracer span begin/end
# ======================================================================== #

def test_unbalanced_begin_span_flagged():
    findings = _lint("""
        def step(tracer):
            tracer.begin_span("engine", "tick")
            do_work()           # a raise here leaks the open span
    """)
    assert [f.rule for f in findings] == ["PUL106"]
    assert "step" in findings[0].message


def test_end_without_begin_flagged():
    assert _rules("""
        def close(tracer):
            tracer.end_span("engine")
    """) == ["PUL106"]


def test_balanced_spans_are_clean():
    assert _rules("""
        def step(tracer):
            tracer.begin_span("engine", "tick")
            do_work()
            tracer.end_span("engine")
    """) == []


def test_with_span_is_clean():
    assert _rules("""
        def step(tracer):
            with tracer.span("engine", "tick"):
                do_work()
    """) == []


def test_async_spans_are_exempt():
    """Cross-scope lifecycle spans pair by id, not by call scope."""
    assert _rules("""
        def submit(tracer, rid):
            tracer.async_begin("requests", "req", rid)

        def finish(tracer, rid):
            tracer.async_end("requests", "req", rid)
    """) == []


def test_nested_function_is_its_own_scope():
    """A balanced pair split across a closure boundary is NOT balanced:
    each scope is checked on its own."""
    assert _rules("""
        def outer(tracer):
            tracer.begin_span("engine", "tick")
            def cleanup():
                tracer.end_span("engine")
            return cleanup
    """) == ["PUL106", "PUL106"]


def test_pul106_waiver_works():
    assert _rules("""
        def step(tracer):
            tracer.begin_span("engine", "tick")  # pul-lint: disable=PUL106
            do_work()
    """) == []


# ======================================================================== #
# waivers + infrastructure
# ======================================================================== #

def test_waiver_comment_suppresses_finding():
    assert _rules("""
        def f():
            try:
                g()
            except:  # pul-lint: disable=PUL105
                pass
    """) == []


def test_waiver_all_suppresses_everything_on_the_line():
    assert _rules("""
        def admit(reqs=[]):  # pul-lint: disable=all
            return reqs
    """) == []


def test_waiver_for_other_rule_does_not_suppress():
    assert _rules("""
        def admit(reqs=[]):  # pul-lint: disable=PUL101
            return reqs
    """) == ["PUL104"]


def test_findings_carry_location():
    f = _lint("""
        def admit(reqs=[]):
            return reqs
    """)[0]
    assert f.path == "fixture.py" and f.line == 2
    assert "fixture.py:2" in f.describe()


# ======================================================================== #
# PUL107: non-donated buffer updates in jitted functions
# ======================================================================== #

def test_undonated_at_update_in_jitted_function_flagged():
    findings = _lint("""
        import jax

        @jax.jit
        def commit(store, rows, idx):
            return store.at[idx].set(rows)
    """)
    assert [f.rule for f in findings] == ["PUL107"]
    assert "store" in findings[0].message


def test_donated_argnums_at_update_clean():
    assert _rules("""
        import jax

        def commit(store, rows, idx):
            return store.at[idx].set(rows)

        commit_jit = jax.jit(commit, donate_argnums=(0,))
    """) == []


def test_donate_argnames_at_update_clean():
    assert _rules("""
        import functools
        import jax

        @functools.partial(jax.jit, donate_argnames=("store",))
        def commit(store, rows, idx):
            return store.at[idx].add(rows)
    """) == []


def test_partial_alias_shifts_donated_argnums():
    # jit(partial(f, a), donate_argnums=(0,)) donates f's SECOND arg: the
    # partial consumed the first positional slot
    assert _rules("""
        import functools
        import jax

        def commit(cfg, store, rows):
            return store.at[0].set(rows)

        bound = functools.partial(commit, object())
        commit_jit = jax.jit(bound, donate_argnums=(0,))
    """) == []


def test_at_update_on_local_value_clean():
    # values built inside the function can alias freely; only parameter
    # buffers need donation
    assert _rules("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def fresh(idx):
            buf = jnp.zeros((4,))
            return buf.at[idx].set(1.0)
    """) == []


def test_at_update_in_pallas_kernel_body_exempt():
    # Pallas Refs mutate in place by construction; `*_kernel` bodies are
    # jit contexts for the other rules but exempt from PUL107
    assert _rules("""
        def sweep_kernel(x_ref, o_ref):
            o_ref[...] = x_ref.at[0].set(1.0)
    """) == []


def test_pul107_waivable_inline():
    assert _rules("""
        import jax

        @jax.jit
        def commit(store, idx):
            return store.at[idx].set(0.0)  # pul-lint: disable=PUL107
    """) == []


def test_rule_catalog_is_complete():
    assert set(RULES) == {"PUL101", "PUL102", "PUL103", "PUL104", "PUL105",
                          "PUL106", "PUL107"}


# ======================================================================== #
# the CI gate: the real tree lints clean
# ======================================================================== #

def test_src_repro_lints_clean():
    root = Path(__file__).resolve().parent.parent
    findings = lint_paths([root / "src" / "repro"])
    assert findings == [], "\n".join(f.describe() for f in findings)


def test_benchmarks_and_tools_lint_clean():
    root = Path(__file__).resolve().parent.parent
    findings = lint_paths([root / "benchmarks", root / "tools"])
    assert findings == [], "\n".join(f.describe() for f in findings)

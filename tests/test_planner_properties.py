"""Property tests for the planner's d* = ceil(T_io/T_c) plateau math."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    DMAEngine,
    MICROBLAZE,
    NVM,
    PULConfig,
    MemoryTier,
    kv_page_bytes,
    kv_page_flops,
    optimal_distance,
    plan_kv_page_stream,
    plan_stream,
)


@settings(max_examples=60, deadline=None)
@given(
    t_c=st.integers(1, 100_000),
    t_io=st.integers(1, 100_000),
    extra_latency=st.integers(0, 100_000),
)
def test_dstar_monotone_in_latency(t_c, t_io, extra_latency):
    """Larger I/O latency never SHRINKS d* (the plateau only moves right)."""
    d1 = optimal_distance(t_c * 1e-9, t_io * 1e-9)
    d2 = optimal_distance(t_c * 1e-9, (t_io + extra_latency) * 1e-9)
    assert d2 >= d1


@settings(max_examples=60, deadline=None)
@given(
    t_c=st.integers(1, 100_000),
    t_io=st.integers(1, 100_000),
    faster=st.integers(1, 1_000),
)
def test_dstar_antitone_in_compute(t_c, t_io, faster):
    """More compute per block (a wider window per request) never GROWS d*."""
    d1 = optimal_distance(t_c * 1e-9, t_io * 1e-9)
    d2 = optimal_distance((t_c + faster) * 1e-9, t_io * 1e-9)
    assert d2 <= d1


@settings(max_examples=40, deadline=None)
@given(
    block=st.sampled_from([64, 256, 1024, 4096]),
    flops=st.integers(1, 50_000),
    deeper=st.integers(0, 48),
)
def test_beyond_dstar_never_faster(block, flops, deeper):
    """Distances beyond d* never raise modeled throughput (Fig. 5-A
    plateau): simulated time at d* is <= time at any deeper distance,
    within the issue-cost epsilon of the discrete-event model."""
    eng = DMAEngine(NVM, MICROBLAZE)
    plan = plan_stream(block_bytes=block, flops_per_block=flops,
                       tier=NVM, pe=MICROBLAZE)
    d_star = plan.cfg.distance
    d_deep = min(64, d_star + deeper)
    kw = dict(n_blocks=128, block_bytes=block, compute_flops_per_block=flops)
    t_star = eng.run_stream(PULConfig(distance=d_star), **kw).total_time
    t_deep = eng.run_stream(PULConfig(distance=d_deep), **kw).total_time
    assert t_star <= t_deep * 1.02


@settings(max_examples=40, deadline=None)
@given(
    t_c=st.integers(1, 10_000),
    t_io=st.integers(1, 10_000),
)
def test_dstar_is_smallest_covering_window(t_c, t_io):
    """d* covers the latency (d* * T_c >= T_io) and is minimal, modulo
    the FIFO cap."""
    tc, tio = t_c * 1e-9, t_io * 1e-9
    d = optimal_distance(tc, tio, fifo_depth=64)
    if d < 64:
        assert d * tc >= tio
        if d > 1:
            assert (d - 1) * tc < tio


@settings(max_examples=30, deadline=None)
@given(
    page_tokens=st.sampled_from([8, 16, 32, 64]),
    kv_features=st.integers(16, 4096),
    gqa=st.sampled_from([1, 2, 4, 8]),
    slow_read=st.integers(1, 10_000),
)
def test_kv_page_plan_monotone_in_tier_latency(page_tokens, kv_features,
                                               gqa, slow_read):
    """The KV-page planning entry inherits d* monotonicity: a slower tier
    never shrinks the planned restore distance."""
    fast = MemoryTier("a", read_latency=100e-9, write_latency=100e-9,
                      bandwidth=8 * 2**30)
    slow = MemoryTier("b", read_latency=100e-9 + slow_read * 1e-8,
                      write_latency=100e-9, bandwidth=8 * 2**30)
    kw = dict(page_tokens=page_tokens, kv_features=kv_features,
              gqa_group=gqa, pe=MICROBLAZE)
    d_fast = plan_kv_page_stream(tier=fast, **kw).cfg.distance
    d_slow = plan_kv_page_stream(tier=slow, **kw).cfg.distance
    assert d_slow >= d_fast


def test_kv_page_units():
    assert kv_page_bytes(16, 128) == 16 * 128 * 2
    assert kv_page_flops(16, 128, gqa_group=4) == 4.0 * 16 * 128 * 4
    plan = plan_kv_page_stream(page_tokens=16, kv_features=128,
                               tier=NVM, pe=MICROBLAZE)
    assert 1 <= plan.cfg.distance <= 64
    assert plan.predicted_time_per_block > 0

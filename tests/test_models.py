"""Model zoo: per-arch smoke tests, decode parity, layer-math oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, SHAPES, get_config
from repro.models import build_model, demo_batch, input_specs
from repro.models import layers as L
from repro.models import module as M
from repro.models import ssm as SSM

ARCHS = sorted(CONFIGS)


# ------------------------------------------------------------- arch smoke
@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_train_step(arch):
    """Reduced config: one forward/loss on CPU; shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = demo_batch(cfg, 2, 16)
    loss = jax.jit(m.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    # random-init loss should be ~ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_grads_finite(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = demo_batch(cfg, 2, 8)
    grads = jax.jit(jax.grad(m.loss))(params, batch)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_parity(arch):
    """prefill + step-by-step decode == full forward logits."""
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S, n_dec = 2, 12, 3
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                cfg.vocab_size, jnp.int32)
    Tf = cfg.frontend_tokens if cfg.frontend else 0
    fe = None
    if Tf:
        fe = (jax.random.normal(jax.random.PRNGKey(3), (B, Tf, cfg.d_model))
              .astype(jnp.bfloat16) * 0.02)

    def full(toks):
        b = {"tokens": toks}
        if fe is not None:
            b["frontend_embeds"] = fe
        return m.prefill(params, b)[0]

    S0 = S - n_dec
    b0 = {"tokens": tokens[:, :S0]}
    if fe is not None:
        b0["frontend_embeds"] = fe
    logits, caches = m.prefill(params, b0, max_seq=S + Tf)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full(tokens[:, :S0])),
                               atol=1e-3)
    for t in range(S0, S):
        pos0 = jnp.full((B,), t + Tf, jnp.int32)
        logits, caches = m.decode_step(
            params, {"tokens": tokens[:, t:t + 1], "pos0": pos0}, caches)
        want = full(tokens[:, :t + 1])
        np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                                   atol=0.05)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if not cfg.shape_applicable(shape):
            continue
        spec = input_specs(cfg, shape)
        assert "batch" in spec and "batch_logical" in spec
        flat_b = jax.tree.leaves(spec["batch"])
        assert all(hasattr(x, "shape") for x in flat_b)
        if shape.kind == "decode":
            assert "caches" in spec


# ----------------------------------------------------------- layer oracles
def test_chunked_attend_matches_dense():
    B, H, T, hd = 2, 4, 256, 32
    K = 2
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, K, hd)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, K, hd))
    mask = L._causal_mask(T, T, offset=0, window=None)
    dense = L._attend(q, k, v, mask=mask, softcap=None, scale=0.2)
    chunked = L._attend_chunked(q, k, v, softcap=None, scale=0.2, window=None,
                                kv_block=64)
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(chunked, np.float32), atol=2e-3)


def test_chunked_attend_window_softcap():
    B, H, T, hd = 1, 2, 128, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, hd)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, hd))
    mask = L._causal_mask(T, T, offset=0, window=32)
    dense = L._attend(q, k, v, mask=mask, softcap=20.0, scale=0.25)
    chunked = L._attend_chunked(q, k, v, softcap=20.0, scale=0.25, window=32,
                                kv_block=48)  # non-dividing block
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(chunked, np.float32), atol=2e-3)


def test_chunked_xent_matches_dense():
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              vocab_chunk=48, vocab_size=200)
    Vp = cfg.padded_vocab          # 240: table rows are padded by contract
    emb = {"table": jax.random.normal(jax.random.PRNGKey(0), (Vp, 64))
           .astype(jnp.bfloat16) * 0.3}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64)).astype(jnp.bfloat16)
    tgt = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 200, jnp.int32)
    mask = jnp.ones((2, 16), jnp.float32)
    got = L.chunked_xent(emb, x, tgt, mask, cfg=cfg)
    logits = jnp.einsum("bsd,vd->bsv", x,
                        emb["table"][:200]).astype(jnp.float32)
    want = jnp.mean(-jax.nn.log_softmax(logits)[
        jnp.arange(2)[:, None], jnp.arange(16)[None], tgt])
    np.testing.assert_allclose(float(got), float(want), rtol=2e-3)


def test_rwkv_chunked_matches_recurrent():
    B, S, H, N = 2, 64, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, N))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.5)
    u = jax.random.normal(ks[4], (H, N)) * 0.5
    S0 = jnp.zeros((B, H, N, N))
    for chunk in (8, 16, 64):
        oc, sc = SSM.rwkv_wkv_chunked(r, k, v, logw, u, S0, chunk)
        orr, sr = SSM.rwkv_wkv_recurrent(r, k, v, logw, u, S0)
        np.testing.assert_allclose(np.asarray(oc), np.asarray(orr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(sc), np.asarray(sr),
                                   rtol=1e-4, atol=1e-4)


def test_rwkv_chunked_nonmultiple_seq():
    B, S, H, N = 1, 23, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, N)) for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.3)
    u = jax.random.normal(ks[4], (H, N))
    S0 = jnp.zeros((B, H, N, N))
    oc, sc = SSM.rwkv_wkv_chunked(r, k, v, logw, u, S0, 8)
    orr, sr = SSM.rwkv_wkv_recurrent(r, k, v, logw, u, S0)
    np.testing.assert_allclose(np.asarray(oc), np.asarray(orr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sr), atol=1e-4)


def test_mamba_chunked_matches_recurrent():
    B, S, H, P, N = 2, 48, 3, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    B_ = jax.random.normal(ks[1], (B, S, N))
    C_ = jax.random.normal(ks[2], (B, S, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    la = -jnp.exp(jax.random.normal(ks[4], (B, S, H)) * 0.3) * dt
    S0 = jnp.zeros((B, H, P, N))
    oc, sc = SSM.mamba_ssd_chunked(x, B_, C_, la, dt, S0, 16)
    orr, sr = SSM.mamba_ssd_recurrent(x, B_, C_, la, dt, S0)
    np.testing.assert_allclose(np.asarray(oc), np.asarray(orr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sr),
                               rtol=1e-4, atol=1e-4)


def test_local_ring_cache_equals_window_attention():
    """gemma-style: decoding with a ring cache == full attention with the
    sliding-window mask."""
    cfg = get_config("gemma2-27b").reduced()
    assert cfg.sliding_window == 16
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 40   # long enough that the ring wraps (40 > 16)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                                cfg.vocab_size, jnp.int32)
    logits_full = m.prefill(params, {"tokens": tokens})[0]
    _, caches = m.prefill(params, {"tokens": tokens[:, :S - 1]}, max_seq=S)
    logits_dec, _ = m.decode_step(
        params, {"tokens": tokens[:, S - 1:], "pos0": jnp.full((B,), S - 1,
                                                               jnp.int32)},
        caches)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full), atol=0.05)


def test_moe_einsum_vs_gather_equivalence():
    """Both dispatch backends agree when capacity drops nothing."""
    import dataclasses as dc
    from repro.models import moe as MOE
    cfg = dc.replace(get_config("grok-1-314b").reduced(),
                     capacity_factor=8.0)  # no drops
    p = M.init_tree(jax.random.PRNGKey(0), MOE.moe_params(cfg))
    x = (jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
         .astype(jnp.bfloat16) * 0.5)
    y1, _ = MOE.moe_apply(p, x, cfg=dc.replace(cfg, moe_backend="einsum"))
    y2, _ = MOE.moe_apply(p, x, cfg=dc.replace(cfg, moe_backend="gather"))
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=2e-2)


def test_param_counts_full_configs():
    """Full-size param counts are in the advertised ballpark."""
    # zamba2 lands at 4.7B from the assignment's dims (the HF 7.4B variant
    # has wider mamba internals than the spec'd ssm_state=64 / d_ff=14336)
    expected = {"qwen2.5-32b": (31e9, 36e9), "deepseek-v2-236b": (220e9, 250e9),
                "grok-1-314b": (290e9, 335e9), "rwkv6-7b": (6e9, 9e9),
                "gemma2-27b": (25e9, 30e9), "zamba2-7b": (4e9, 9e9),
                "gemma3-12b": (10.5e9, 14e9)}
    for arch, (lo, hi) in expected.items():
        n = build_model(get_config(arch)).num_params()
        assert lo < n < hi, f"{arch}: {n:,} params not in [{lo:,}, {hi:,}]"

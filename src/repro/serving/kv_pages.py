"""Paged KV cache managed by the PUL engine.

The serving-side realization of the paper's tiered-memory model: KV state is
split into fixed-size *pages* of ``page_tokens`` tokens (tile-aligned per
``core.pul.TPU_SUBLANE``), living in a pool of physical frames split across

  * a **hot tier** — the fast memory the decode kernels read (HBM on TPU;
    jnp arrays here), bounded at ``hot_frames`` pages, and
  * a **cold tier** — the slow memory (host DRAM / remote HBM; a numpy dict
    here) that evicted pages spill to, with real data movement both ways.

Eviction emits UNLOAD descriptors and restore emits PRELOAD descriptors
(`core.pul.TransferRequest`); restores are *planned*: `core.planner`
derives the preload distance d* = ceil(T_io / T_c) from page transfer time
vs per-page decode compute, and the restore batch is replayed through the
discrete-event twin (`core.dma`) so the engine reports how much restore
latency the schedule hides — the paper's claim, measured per serving step.

Hot storage comes in two layouts, both behind the versioned
:class:`KVStoreLayout` protocol (``KV_LAYOUT_VERSION``):

  * **per-layer planes** (v2, the kernel-true serving layout): each pageable
    cache leaf owns a *plane* whose leading axis is the layer (scan-group)
    index — attention leaves are ``(L, NF, K, P, hd)``, MLA's compressed
    leaves ``(L, NF, P, kvr)``. A plane IS the page-frame layout the decode
    kernels consume, so ``layer_view`` / ``page_view_tree`` are pure
    indexing — zero-copy under jit, no gather, no transpose — and the
    single-sweep decode kernel walks all layers of one plane with a
    prefetched layer scalar. The current token's rows are committed either
    *fused* (in the sweep kernel's epilogue, see
    ``kernels.pul_paged_sweep_decode_attention``) or *eagerly* via
    :meth:`KVStoreLayout.commit_token`.
  * **packed rows** (v1, the portable/oracle layout): token t of a page is
    one ``(F,)`` row concatenating every layer's features
    (:class:`PackedKVLayout` ``pack``/``unpack``); kept for the dense
    assembly oracle and for direct pool users (``KVPagePool(pcfg,
    features=F)``).

The cold tier always holds packed ``(P, F)`` rows regardless of the hot
layout, so UNLOAD/PRELOAD byte accounting, the DMA twin's KV-page workload,
and the lifecycle sanitizer are layout-independent.

Page *contents* pack every attention layer's K and V for a token range into
one logical page, so one page id covers the whole model and a prefix page
can be shared by every request with that prompt prefix (refcounted; only
full, immutable prompt pages are shared).
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.events import EventKind, TraceLog
from repro.configs.base import ModelConfig
from repro.obs.tracer import NULL_TRACER
from repro.core.dma import DMAEngine, KVPageWorkload, run_kv_page_workload
from repro.core.planner import kv_page_flops, plan_kv_page_stream
from repro.core.pul import (
    Direction,
    MemoryTier,
    PEModel,
    HBM,
    REMOTE_HBM,
    TPU_SUBLANE,
    TPU_V5E_VPU,
    TransferRequest,
)

# kv-bearing cache leaves (dict key -> leaf is pageable when its seq axis
# matches max_seq): standard GQA attention and MLA's compressed cache
_KV_LEAF_KEYS = ("k", "v", "c_kv", "k_rope")

#: Version of the KV store-layout protocol. v1 was the ad-hoc
#: ``page_views``/``pack_new_rows`` pair over a single packed store plane;
#: v2 is the per-layer-plane :class:`KVStoreLayout` protocol below.
KV_LAYOUT_VERSION = 2


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(getattr(p, "key", str(p)) for p in path)


@dataclasses.dataclass(frozen=True)
class _LeafEntry:
    keys: Tuple[str, ...]       # dict path into the cache tree
    shape: Tuple[int, ...]      # full leaf shape
    grouped: bool               # True: (G, B, S, feat...); False: (B, S, feat...)
    nfeat: int                  # packed per-token features of this leaf
    offset: int                 # column offset in the packed row

    @property
    def plane_key(self) -> str:
        """Stable string id of this entry's store plane ("groups/0:global/k")."""
        return "/".join(self.keys)

    @property
    def feat(self) -> Tuple[int, ...]:
        """Per-token feature dims: (K, hd) for attention, (kvr,) for MLA."""
        return self.shape[3:] if self.grouped else self.shape[2:]

    @property
    def layers(self) -> int:
        """Leading layer (scan-group) extent of this entry's plane."""
        return self.shape[0] if self.grouped else 1


class KVStoreLayout:
    """Versioned protocol between the page pool, the decode kernels, the
    engine, and the DMA benchmark (``KV_LAYOUT_VERSION = 2``).

    A layout owns the mapping between a model's cache tree and physical
    page *planes* — one jnp array per pageable cache leaf, laid out so the
    kernels consume it directly:

      * attention leaves: ``(L, NF, K, P, hd)`` (layer, frame, kv head,
        page token, head dim)
      * MLA compressed leaves: ``(L, NF, P, feat)``

    with ``L`` the leaf's layer extent (scan groups; 1 for unscanned
    leaves), ``NF`` the pool's hot-frame count, and ``P`` tokens per page.

    Required interface (all pure jnp unless stated):

      * :meth:`init_planes` — allocate zeroed planes for ``NF`` frames.
      * :meth:`layer_view` — ``{plane_key: (NF, ...) page frames}`` of one
        layer. **Zero-copy**: pure leading-axis indexing, no gather or
        transpose under jit (property-tested in
        ``tests/test_paged_sweep.py``).
      * :meth:`page_view_tree` — a cache tree whose pageable leaves are
        whole planes (grouped leaves keep their leading scan axis); the
        per-layer decode kernels address it directly.
      * :meth:`commit_token` — the *eager* commit: scatter one packed row
        per slot into ``(frame, offset)``. The *fused* commit is the same
        contract implemented in the sweep kernel's epilogue
        (``kernels.pul_paged_sweep_decode_attention``); the pool accounts
        it via :meth:`KVPagePool.note_fused_commit`.
      * :meth:`read_frame_packed` / :meth:`write_frame_packed` — bridge one
        frame to the packed ``(P, F)`` row layout the cold tier and DMA
        descriptors use (tier movement is layout-independent).
      * :meth:`pack_planes` — materialize the packed ``(NF, P, F)`` store
        (a copy; oracle/assembly path only).

    ``features`` (the packed row width F) and ``entries`` describe the
    geometry; ``layout_version`` pins the protocol revision a layout
    implements.
    """

    layout_version: int = KV_LAYOUT_VERSION
    features: int = 0
    entries: List[_LeafEntry] = []

    def init_planes(self, n_frames: int, page_tokens: int,
                    dtype) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def layer_view(self, planes: Dict[str, jnp.ndarray],
                   layer: int) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def page_view_tree(self, tree: Any,
                       planes: Dict[str, jnp.ndarray]) -> Any:
        raise NotImplementedError

    def commit_token(self, planes: Dict[str, jnp.ndarray],
                     rows: jnp.ndarray, frames, offsets,
                     dtype) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def read_frame_packed(self, planes: Dict[str, jnp.ndarray],
                          frame: int) -> np.ndarray:
        raise NotImplementedError

    def write_frame_packed(self, planes: Dict[str, jnp.ndarray], frame: int,
                           rows, dtype) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def pack_planes(self, planes: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        raise NotImplementedError


class PackedKVLayout(KVStoreLayout):
    """Mapping between a model's cache tree and its paged KV store.

    Implements :class:`KVStoreLayout` v2 (per-layer planes) and keeps the
    v1 packed-row codec: token t of slot b occupies row (b, t) — the
    concatenation over every pageable cache leaf of that token's features
    (all layers, all kv heads). `pack`/`unpack` are pure jnp functions
    (jit-able, shape-polymorphic in S so prefill buckets and the decode
    max_seq share one layout).
    """

    layout_version = KV_LAYOUT_VERSION

    def __init__(self, cfg: ModelConfig, batch: int, max_seq: int):
        from repro.models import transformer as T
        spec, _ = T.cache_specs(cfg, batch, max_seq)
        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self.entries: List[_LeafEntry] = []
        off = 0
        flat, _ = jax.tree_util.tree_flatten_with_path(spec)
        for path, leaf in sorted(flat, key=lambda kv: _path_keys(kv[0])):
            keys = _path_keys(path)
            if keys[-1] not in _KV_LEAF_KEYS:
                continue
            grouped = keys[0] == "groups"
            seq_ax = 2 if grouped else 1
            if len(leaf.shape) <= seq_ax or leaf.shape[seq_ax] != max_seq:
                continue
            nfeat = int(np.prod(leaf.shape)) // (batch * max_seq)
            self.entries.append(_LeafEntry(keys, tuple(leaf.shape), grouped,
                                           nfeat, off))
            off += nfeat
        self.features = off

    # ------------------------------------------------------------------ #
    def _get(self, tree: Any, keys: Tuple[str, ...]) -> Any:
        node = tree
        for k in keys:
            node = node[k]
        return node

    def _leaf_rows(self, leaf: jnp.ndarray, e: _LeafEntry) -> jnp.ndarray:
        """(B, S, nfeat) view of one cache leaf."""
        if e.grouped:                       # (G, B, S, feat...) -> (B, S, -1)
            G, B, S = leaf.shape[:3]
            x = jnp.moveaxis(leaf, 0, 2)    # (B, S, G, feat...)
            return x.reshape(B, S, -1)
        B, S = leaf.shape[:2]
        return leaf.reshape(B, S, -1)

    def pack(self, tree: Any) -> jnp.ndarray:
        """Cache tree -> (B, S, F) packed KV rows (S = tree's seq size)."""
        return jnp.concatenate(
            [self._leaf_rows(self._get(tree, e.keys), e)
             for e in self.entries], axis=-1)

    def pack_rows(self, tree: Any, idx: jnp.ndarray) -> jnp.ndarray:
        """One row per slot: (B, F) at per-slot positions `idx` (B,)."""
        B = idx.shape[0]
        rows = jnp.arange(B)
        outs = []
        for e in self.entries:
            leaf = self._get(tree, e.keys)
            S = leaf.shape[2 if e.grouped else 1]
            i = jnp.clip(idx, 0, S - 1)
            if e.grouped:
                x = jnp.moveaxis(leaf, 0, 2)        # (B, S, G, feat...)
                outs.append(x[rows, i].reshape(B, -1))
            else:
                outs.append(leaf[rows, i].reshape(B, -1))
        return jnp.concatenate(outs, axis=-1)

    def _pack_new_rows_impl(self, tree: Any) -> jnp.ndarray:
        outs = []
        for e in self.entries:
            leaf = self._get(tree, e.keys)
            if e.grouped:
                B = leaf.shape[1]
                outs.append(jnp.moveaxis(leaf, 0, 1).reshape(B, -1))
            else:
                outs.append(leaf.reshape(leaf.shape[0], -1))
        return jnp.concatenate(outs, axis=-1)

    def pack_new_rows(self, tree: Any) -> jnp.ndarray:
        """Deprecated v1 API: pack a paged-decode output tree's NEW-TOKEN
        rows into (B, F) for an out-of-kernel scatter.

        `tree` holds only the current token's features per pageable leaf —
        grouped (G, B, feat...) or ungrouped (B, feat...) — in `pack` entry
        order. Superseded by the :class:`KVStoreLayout` commit contract:
        the sweep kernel commits rows in its fused epilogue
        (`KVPagePool.note_fused_commit`) and the eager fallback is
        :meth:`commit_token` / `KVPagePool.write_rows`."""
        warnings.warn(
            "PackedKVLayout.pack_new_rows is deprecated; the KVStoreLayout "
            "protocol commits new-token rows fused (sweep-kernel epilogue) "
            "or eagerly via commit_token/KVPagePool.write_rows",
            PendingDeprecationWarning, stacklevel=2)
        return self._pack_new_rows_impl(tree)

    def _page_views_packed(self, tree: Any, store: jnp.ndarray) -> Any:
        NP, P, _ = store.shape
        new = jax.tree_util.tree_map(lambda x: x, tree)
        for e in self.entries:
            cols = store[:, :, e.offset:e.offset + e.nfeat]   # (NP, P, nfeat)
            feat = e.feat
            if e.grouped:
                G = e.shape[0]
                view = jnp.moveaxis(cols.reshape(NP, P, G, *feat), 2, 0)
            else:
                view = cols.reshape(NP, P, *feat)
            if len(feat) == 2:              # (K, hd) -> pages (.., NP, K, P, hd)
                view = jnp.swapaxes(view, -3, -2)
            node = new
            for k in e.keys[:-1]:
                node = node[k]
            node[e.keys[-1]] = view
        return new

    def page_views(self, tree: Any, store: jnp.ndarray) -> Any:
        """Deprecated v1 API: slice a PACKED store ((NP, P, F)) into
        per-layer kernel views — a gather/transpose under jit every step.

        Superseded by :meth:`page_view_tree` over per-layer planes, where
        the "view" is the stored array itself (zero-copy). Kept for one
        release for direct packed-store users."""
        warnings.warn(
            "PackedKVLayout.page_views is deprecated; use the KVStoreLayout "
            "protocol (page_view_tree/layer_view over per-layer planes, "
            "which are zero-copy) instead",
            PendingDeprecationWarning, stacklevel=2)
        return self._page_views_packed(tree, store)

    def unpack_into(self, tree: Any, packed: jnp.ndarray) -> Any:
        """Return `tree` with every pageable leaf replaced from `packed`
        ((B, S, F)); non-pageable leaves (SSM states, idx) pass through."""
        B, S, _ = packed.shape
        # tree_map rebuilds every container, so in-place edits below only
        # touch the fresh copy, never the caller's tree
        new = jax.tree_util.tree_map(lambda x: x, tree)
        for e in self.entries:
            cols = packed[..., e.offset:e.offset + e.nfeat]
            if e.grouped:
                G = e.shape[0]
                feat = e.shape[3:]
                leaf = jnp.moveaxis(cols.reshape(B, S, G, *feat), 2, 0)
            else:
                leaf = cols.reshape(B, S, *e.shape[2:])
            node = new
            for k in e.keys[:-1]:
                node = node[k]
            node[e.keys[-1]] = leaf.astype(self._get(tree, e.keys).dtype)
        return new

    # ------------------------------------------------------------------ #
    # KVStoreLayout v2: per-layer planes
    # ------------------------------------------------------------------ #
    def plane_shape(self, e: _LeafEntry, n_frames: int,
                    page_tokens: int) -> Tuple[int, ...]:
        feat = e.feat
        if len(feat) == 2:                  # attention: (L, NF, K, P, hd)
            return (e.layers, n_frames, feat[0], page_tokens, feat[1])
        return (e.layers, n_frames, page_tokens, *feat)   # MLA: (L, NF, P, f)

    def init_planes(self, n_frames: int, page_tokens: int,
                    dtype) -> Dict[str, jnp.ndarray]:
        """Zeroed per-layer page planes for `n_frames` physical frames."""
        return {e.plane_key: jnp.zeros(
                    self.plane_shape(e, n_frames, page_tokens), dtype)
                for e in self.entries}

    def layer_view(self, planes: Dict[str, jnp.ndarray],
                   layer: int) -> Dict[str, jnp.ndarray]:
        """One layer's page frames per plane — pure leading-axis indexing
        (zero-copy under jit): attention planes yield (NF, K, P, hd),
        MLA planes (NF, P, feat). Unscanned (L == 1) entries ignore
        `layer`."""
        return {e.plane_key:
                planes[e.plane_key][layer if e.layers > 1 else 0]
                for e in self.entries}

    def page_view_tree(self, tree: Any,
                       planes: Dict[str, jnp.ndarray]) -> Any:
        """Return `tree` with every pageable leaf replaced by its plane —
        THE stored array, not a slice of one (grouped leaves keep their
        leading scan axis; unscanned leaves drop their singleton layer
        axis). This is what makes the kernel-true decode zero-copy: the
        leaf the kernel addresses is the buffer the pool owns."""
        new = jax.tree_util.tree_map(lambda x: x, tree)
        for e in self.entries:
            plane = planes[e.plane_key]
            view = plane if e.grouped else plane[0]
            node = new
            for k in e.keys[:-1]:
                node = node[k]
            node[e.keys[-1]] = view
        return new

    def commit_token(self, planes: Dict[str, jnp.ndarray],
                     rows: jnp.ndarray, frames, offsets,
                     dtype) -> Dict[str, jnp.ndarray]:
        """Eager commit: scatter one packed (F,) row per slot into its
        (frame, offset) page position across every plane. The fused
        equivalent runs in the sweep kernel's epilogue."""
        frames = jnp.asarray(frames, jnp.int32)
        offsets = jnp.asarray(offsets, jnp.int32)
        B = rows.shape[0]
        out = dict(planes)
        for e in self.entries:
            cols = rows[:, e.offset:e.offset + e.nfeat].astype(dtype)
            plane = planes[e.plane_key]
            feat = e.feat
            if len(feat) == 2:
                vals = cols.reshape(B, e.layers, *feat)       # (B, L, K, hd)
                # advanced indices (frames @ axis 1, offsets @ axis 3) are
                # separated by a slice, so the broadcast B axis leads
                out[e.plane_key] = plane.at[:, frames, :, offsets, :].set(vals)
            else:
                vals = cols.reshape(B, e.layers, *feat)       # (B, L, f)
                # adjacent advanced indices keep their position: (L, B, f)
                out[e.plane_key] = plane.at[:, frames, offsets, :].set(
                    jnp.moveaxis(vals, 0, 1))
        return out

    def read_frame_packed(self, planes: Dict[str, jnp.ndarray],
                          frame: int) -> np.ndarray:
        """One frame's packed (P, F) rows (numpy; cold-tier spill format)."""
        cols = []
        for e in self.entries:
            sl = np.asarray(planes[e.plane_key][:, frame])
            if len(e.feat) == 2:            # (L, K, P, hd) -> (P, L*K*hd)
                sl = sl.transpose(2, 0, 1, 3)
            else:                           # (L, P, f) -> (P, L*f)
                sl = sl.transpose(1, 0, 2)
            cols.append(sl.reshape(sl.shape[0], -1))
        return np.concatenate(cols, axis=-1)

    def write_frame_packed(self, planes: Dict[str, jnp.ndarray], frame: int,
                           rows, dtype) -> Dict[str, jnp.ndarray]:
        """Fill one frame from packed (P, F) rows (cold-tier restore /
        prefill page fill); returns the updated planes dict."""
        rows = jnp.asarray(rows).astype(dtype)
        P = rows.shape[0]
        out = dict(planes)
        for e in self.entries:
            cols = rows[:, e.offset:e.offset + e.nfeat]
            feat = e.feat
            if len(feat) == 2:              # (P, L, K, hd) -> (L, K, P, hd)
                vals = cols.reshape(P, e.layers, *feat).transpose(1, 2, 0, 3)
            else:                           # (P, L, f) -> (L, P, f)
                vals = cols.reshape(P, e.layers, *feat).transpose(1, 0, 2)
            out[e.plane_key] = planes[e.plane_key].at[:, frame].set(vals)
        return out

    def pack_planes(self, planes: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Materialize the packed (NF, P, F) store from the planes — a
        COPY; only the dense-assembly oracle path pays it."""
        cols = []
        for e in self.entries:
            plane = planes[e.plane_key]
            if len(e.feat) == 2:            # (L,NF,K,P,hd) -> (NF,P,L,K,hd)
                sl = jnp.transpose(plane, (1, 3, 0, 2, 4))
            else:                           # (L,NF,P,f) -> (NF,P,L,f)
                sl = jnp.transpose(plane, (1, 2, 0, 3))
            cols.append(sl.reshape(sl.shape[0], sl.shape[1], -1))
        return jnp.concatenate(cols, axis=-1)


# -------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PageConfig:
    """Knobs of the paged-KV pool (the serving face of PULConfig)."""

    page_tokens: int = 16               # tokens per page, TPU_SUBLANE-aligned
    hot_frames: int = 0                 # 0 -> sized to fit every live slot
    fast_tier: MemoryTier = HBM
    slow_tier: MemoryTier = REMOTE_HBM
    pe: PEModel = TPU_V5E_VPU
    preload_distance: Optional[int] = None   # None -> planner d*
    fifo_depth: int = 64
    share_prefix_pages: bool = True
    trace: bool = False             # record page-lifecycle events for the
                                    # sanitizer (repro.analysis); off = the
                                    # pool never touches the trace path, so
                                    # production pays zero overhead

    def __post_init__(self):
        if self.page_tokens % TPU_SUBLANE != 0:
            raise ValueError(
                f"page_tokens ({self.page_tokens}) must be a multiple of "
                f"TPU_SUBLANE ({TPU_SUBLANE}) to keep page DMAs tile-aligned")


@dataclasses.dataclass
class PoolMetrics:
    page_faults: int = 0        # pages restored from the cold tier
    evictions: int = 0          # pages written out to the cold tier
    shared_hits: int = 0        # prompt pages reused via prefix sharing
    pages_allocated: int = 0
    modeled_restore_time: float = 0.0   # DMA-twin time of all restore batches
    modeled_restore_stall: float = 0.0  # PE stall within those batches
    # cache-economics counters (repro.obs.metrics.cache_economics):
    bytes_hot_written: int = 0  # bytes scattered into the hot store (prefill
                                # page fills + decode row commits, fused or
                                # eager)
    # prefetch-quality counters for planned d* restores (accuracy /
    # timeliness / coverage, per the prefetching survey in PAPERS.md):
    planned_preloads: int = 0   # restores issued through ensure_hot's
                                # planned d* batch
    unplanned_restores: int = 0  # demand restores outside a planned batch
                                # (none today; exists so a speculative
                                # planner's misses become visible)
    useful_preloads: int = 0    # restored pages read before re-eviction
    wasted_preloads: int = 0    # restored pages evicted/freed unread
    descriptors: List[TransferRequest] = dataclasses.field(default_factory=list)

    @property
    def modeled_latency_hidden(self) -> float:
        """Fraction of restore wall-time the planned preload overlapped."""
        if self.modeled_restore_time <= 0:
            return 1.0
        return 1.0 - self.modeled_restore_stall / self.modeled_restore_time

    def validate(self) -> None:
        """Cross-check the counters' arithmetic invariants; raises
        ValueError naming the broken one. Called from the engine's metrics
        hook so a drifted counter surfaces at the snapshot that drifted,
        not in a downstream report."""
        for name in ("page_faults", "evictions", "shared_hits",
                     "pages_allocated", "bytes_hot_written",
                     "planned_preloads", "unplanned_restores",
                     "useful_preloads", "wasted_preloads"):
            v = getattr(self, name)
            if v < 0:
                raise ValueError(f"PoolMetrics.{name} is negative ({v})")
        if (self.useful_preloads + self.wasted_preloads
                > self.planned_preloads + self.unplanned_restores):
            raise ValueError(
                "PoolMetrics: more preload outcomes (useful + wasted) than "
                "restores issued")
        if self.modeled_restore_time < 0 or self.modeled_restore_stall < 0:
            raise ValueError("PoolMetrics modeled restore times are negative")
        # every restore re-loads a page that previously spilled: the planned
        # preloads (PRELOAD descriptors) must pair 1:1 with page faults, and
        # can never outnumber the evictions that created cold copies
        preloads = sum(1 for d in self.descriptors
                       if d.direction is Direction.PRELOAD)
        unloads = sum(1 for d in self.descriptors
                      if d.direction is Direction.UNLOAD)
        if preloads != self.page_faults:
            raise ValueError(
                f"PoolMetrics: {preloads} PRELOAD descriptors but "
                f"{self.page_faults} page faults (restores must be planned)")
        if unloads != self.evictions:
            raise ValueError(
                f"PoolMetrics: {unloads} UNLOAD descriptors but "
                f"{self.evictions} evictions")
        if self.page_faults > self.evictions:
            raise ValueError(
                f"PoolMetrics: {self.page_faults} restores exceed "
                f"{self.evictions} evictions — a page was restored that "
                "never spilled")
        hidden = self.modeled_latency_hidden
        if not 0.0 <= hidden <= 1.0:
            raise ValueError(
                f"PoolMetrics.modeled_latency_hidden = {hidden} out of "
                "[0, 1]")


@dataclasses.dataclass
class _PageMeta:
    frame: Optional[int]        # hot frame index, or None when cold
    refcount: int = 1
    last_used: int = 0
    shared_key: Optional[tuple] = None
    deadline: float = float("inf")   # owning request's TTFT deadline tick
                                     # (inf: none) — eviction prefers pages
                                     # whose requests can afford the restore
    pending_read: bool = False  # restored but not yet read: cleared at first
                                # READ (a useful preload), still set at the
                                # next evict/free (a wasted one) — the
                                # prefetch-accuracy bookkeeping


ZERO_FRAME = 0      # reserved all-zeros frame (unallocated page-table slots)
TRASH_FRAME = 1     # reserved write sink (inactive slots' decode writes)
RESERVED_FRAMES = 2


class KVPagePool:
    """Physical page frames + residency + refcounts + tier movement.

    Two hot-storage modes behind one lifecycle:

      * ``KVPagePool(pcfg, features=F)`` — packed mode (v1): one
        ``(NF, P, F)`` store array, exposed as ``pool.store``.
      * ``KVPagePool(pcfg, layout=<KVStoreLayout>)`` — per-layer mode (v2):
        storage is ``pool.planes`` (one plane per pageable cache leaf; see
        :class:`KVStoreLayout`) and all data movement delegates to the
        layout. The packed view, when the oracle path needs it, is
        :meth:`packed_store`.

    Frame ids, page ids, refcounts, eviction order, DMA descriptors, and
    the lifecycle trace are identical across modes — a frame spans every
    layer plane, so the cold tier and byte accounting stay packed."""

    def __init__(self, pcfg: PageConfig, features: Optional[int] = None, *,
                 layout: Optional[KVStoreLayout] = None,
                 gqa_group: int = 1, dtype=jnp.bfloat16, tracer=None):
        if (features is None) == (layout is None):
            raise ValueError(
                "KVPagePool takes exactly one of `features` (packed mode) "
                "or `layout` (per-layer mode)")
        self.cfg = pcfg
        self.layout = layout
        self.features = layout.features if layout is not None else features
        self.dtype = dtype
        P = pcfg.page_tokens
        self.page_bytes = P * self.features * jnp.dtype(dtype).itemsize
        self.row_bytes = self.features * jnp.dtype(dtype).itemsize
        n = max(pcfg.hot_frames, RESERVED_FRAMES + 1)
        if layout is not None:
            self.planes: Dict[str, jnp.ndarray] = layout.init_planes(
                n, P, dtype)
            self._n_frames = n
            # layer extent of the store (sweep-kernel SMEM scalar range +
            # per-layer trace provenance)
            self.n_layers = max((e.layers for e in layout.entries), default=1)
        else:
            self.store = jnp.zeros((n, P, self.features), dtype)
            self._n_frames = n
            self.n_layers = 1
        self.free_frames: List[int] = list(range(RESERVED_FRAMES, n))
        self.pages: "OrderedDict[int, _PageMeta]" = OrderedDict()
        self.cold: Dict[int, np.ndarray] = {}
        self.prefix_index: Dict[tuple, int] = {}
        self.metrics = PoolMetrics()
        # lifecycle event trace for the sanitizer (repro.analysis); None
        # when tracing is off — every emission site guards on this, so the
        # untraced hot path never builds an event
        self.trace: Optional[TraceLog] = TraceLog() if pcfg.trace else None
        # unified tracer (repro.obs): page-lifecycle events are bridged into
        # the same stream as engine spans and DMA descriptors; NULL_TRACER
        # keeps every emission site a cheap attribute check when off
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._bridge_seq = 0    # event sequence when TraceLog is off
        self._next_id = 1
        self._clock = 0
        # restore planning: d* from page transfer time vs per-page compute
        self.plan = plan_kv_page_stream(
            page_tokens=P, kv_features=self.features, tier=pcfg.slow_tier,
            pe=pcfg.pe, gqa_group=gqa_group, fifo_depth=pcfg.fifo_depth,
            itemsize=jnp.dtype(dtype).itemsize)
        self.distance = pcfg.preload_distance or self.plan.cfg.distance
        self._dma = DMAEngine(pcfg.slow_tier, pcfg.pe,
                              fifo_depth=pcfg.fifo_depth,
                              tracer=self.tracer)
        self._flops_per_page = kv_page_flops(P, self.features, gqa_group)

    # ------------------------------------------------------------------ #
    @property
    def hot_frames(self) -> int:
        return self._n_frames

    @property
    def capacity(self) -> int:
        """Usable hot frames (page working set must fit here per step)."""
        return self.hot_frames - RESERVED_FRAMES

    def hot_in_use(self) -> int:
        return sum(1 for m in self.pages.values() if m.frame is not None)

    def packed_store(self) -> jnp.ndarray:
        """The packed (NF, P, F) store: the array itself in packed mode, a
        materialized copy of the planes in per-layer mode (oracle path)."""
        if self.layout is not None:
            return self.layout.pack_planes(self.planes)
        return self.store

    # ------------------------------------------------------------------ #
    # layout-dispatched frame data movement (cold tier stays packed)
    # ------------------------------------------------------------------ #
    def _read_frame(self, frame: int) -> np.ndarray:
        if self.layout is not None:
            return self.layout.read_frame_packed(self.planes, frame)
        return np.asarray(self.store[frame])

    def _write_frame(self, frame: int, rows) -> None:
        if self.layout is not None:
            self.planes = self.layout.write_frame_packed(
                self.planes, frame, rows, self.dtype)
        else:
            self.store = self.store.at[frame].set(
                jnp.asarray(rows).astype(self.dtype))

    def _scatter_rows(self, frames, offsets, rows) -> None:
        if self.layout is not None:
            self.planes = self.layout.commit_token(
                self.planes, rows, frames, offsets, self.dtype)
        else:
            self.store = self.store.at[
                jnp.asarray(frames), jnp.asarray(offsets)].set(
                    rows.astype(self.dtype))

    # ------------------------------------------------------------------ #
    def _emit(self, kind: EventKind, **fields) -> None:
        if self.trace is not None:
            self.trace.emit(self._clock, kind, **fields)
        if self.tracer.enabled:
            seq = (self.trace.events[-1].seq if self.trace is not None
                   else self._bridge_seq)
            self._bridge_seq = seq + 1
            self.tracer.page_event(seq, self._clock, kind, fields)

    def tick(self) -> None:
        self._clock += 1
        self._emit(EventKind.TICK)

    def alloc(self, shared_key: Optional[tuple] = None, *,
              needed: Sequence[int] = ()) -> int:
        """Allocate a fresh page in the hot tier; returns its page id.

        `needed` is the caller's CURRENT working set (page ids the ongoing
        step still has to read): frame stealing will never evict them, so an
        allocation can't trigger a same-step fault/restore round-trip."""
        pid = self._next_id
        self._next_id += 1
        frame = self._take_frame(needed=needed)
        self.pages[pid] = _PageMeta(frame=frame, last_used=self._clock,
                                    shared_key=shared_key)
        if shared_key is not None:
            self.prefix_index[shared_key] = pid
        self.metrics.pages_allocated += 1
        self._emit(EventKind.ALLOC, pid=pid, frame=frame, refcount=1,
                   shared_key=shared_key)
        return pid

    def lookup_shared(self, key: tuple) -> Optional[int]:
        if not self.cfg.share_prefix_pages:
            return None
        pid = self.prefix_index.get(key)
        if pid is not None:
            self.pages[pid].refcount += 1
            self.metrics.shared_hits += 1
            self._emit(EventKind.REF, pid=pid,
                       refcount=self.pages[pid].refcount, shared_key=key)
        return pid

    def ref(self, pid: int) -> None:
        self.pages[pid].refcount += 1
        self._emit(EventKind.REF, pid=pid, refcount=self.pages[pid].refcount)

    def unref(self, pid: int) -> None:
        meta = self.pages[pid]
        meta.refcount -= 1
        self._emit(EventKind.UNREF, pid=pid, refcount=meta.refcount)
        if meta.refcount > 0:
            return
        if meta.pending_read:               # freed without ever being read
            meta.pending_read = False
            self.metrics.wasted_preloads += 1
        if meta.shared_key is not None:
            self.prefix_index.pop(meta.shared_key, None)
        if meta.frame is not None:
            self.free_frames.append(meta.frame)
        self.cold.pop(pid, None)
        del self.pages[pid]
        self._emit(EventKind.FREE, pid=pid)

    # ------------------------------------------------------------------ #
    def note_deadline(self, pids: Sequence[int], deadline: float) -> None:
        """Tag pages with their owning request's absolute TTFT-deadline
        tick (inf: no deadline). Eviction orders victims by LATEST deadline
        first — a page whose request has slack can afford the restore
        round-trip; one racing a deadline cannot. The engine refreshes tags
        at every admission/resume, so a shared page carries its most recent
        requester's urgency (a deliberate, cheap approximation)."""
        for pid in pids:
            self.pages[pid].deadline = deadline
            self._emit(EventKind.DEADLINE, pid=pid, deadline=deadline)

    def _take_frame(self, needed: Sequence[int]) -> int:
        """Get a free hot frame, evicting pages not in `needed` — latest
        request deadline first (deadline-aware), then LRU within a tie."""
        if self.free_frames:
            return self.free_frames.pop()
        needed = set(needed)
        victims = sorted(
            ((-m.deadline, m.last_used), pid) for pid, m in self.pages.items()
            if m.frame is not None and pid not in needed)
        if not victims:
            raise RuntimeError(
                f"hot tier exhausted: {self.capacity} frames all needed this "
                "step; raise PageConfig.hot_frames or admit fewer tokens")
        _, victim = victims[0]
        self.evict(victim, cause="steal", pinned=needed)
        return self.free_frames.pop()

    def evict(self, pid: int, *, cause: str = "explicit",
              pinned: Sequence[int] = ()) -> None:
        """Hot -> cold: real data movement + an UNLOAD descriptor.

        `cause` is sanitizer provenance: "steal" marks capacity evictions
        (which must follow the deadline-then-LRU victim order over the
        non-`pinned` hot pages); "explicit" marks policy-driven spills
        (preemption, pause) that are exempt from victim-order checks."""
        meta = self.pages[pid]
        assert meta.frame is not None, f"page {pid} already cold"
        if meta.pending_read:               # restored but never read before
            meta.pending_read = False       # spilling again: wasted preload
            self.metrics.wasted_preloads += 1
        self._emit(EventKind.EVICT, pid=pid, frame=meta.frame, cause=cause,
                   pinned=tuple(sorted(pinned)))
        self.cold[pid] = self._read_frame(meta.frame)
        self.free_frames.append(meta.frame)
        self.metrics.evictions += 1
        self.metrics.descriptors.append(TransferRequest(
            Direction.UNLOAD, src=meta.frame * self.page_bytes,
            dst=pid * self.page_bytes, nbytes=self.page_bytes, tag=pid))
        meta.frame = None

    def evict_pages(self, pids: Sequence[int]) -> None:
        for pid in pids:
            if self.pages[pid].frame is not None:
                self.evict(pid)

    def ensure_hot(self, pids: Sequence[int]) -> int:
        """Restore any cold page in `pids`; returns the page-fault count.

        Restores are issued as one planned batch: preload distance d* (from
        `core.planner`), BATCH issue order, and the batch is replayed on the
        DMA twin to account the modeled stall (the per-step page-fault cost
        a TPU deployment would see).
        """
        self.tick()
        faults = []
        for pid in pids:
            meta = self.pages[pid]
            meta.last_used = self._clock
            self._emit(EventKind.TOUCH, pid=pid)
            if meta.frame is None:
                faults.append(pid)
        for pid in faults:
            meta = self.pages[pid]
            frame = self._take_frame(needed=pids)
            data = self.cold.pop(pid)
            self._write_frame(frame, data)
            meta.frame = frame
            meta.pending_read = True
            self._emit(EventKind.RESTORE, pid=pid, frame=frame)
            self.metrics.descriptors.append(TransferRequest(
                Direction.PRELOAD, src=pid * self.page_bytes,
                dst=frame * self.page_bytes, nbytes=self.page_bytes, tag=pid))
        if faults:
            self.metrics.page_faults += len(faults)
            self.metrics.planned_preloads += len(faults)
            stats = run_kv_page_workload(
                self._dma,
                KVPageWorkload(page_bytes=self.page_bytes,
                               flops_per_page=self._flops_per_page,
                               pages_per_step=len(faults), steps=1),
                distance=self.distance)
            self.metrics.modeled_restore_time += stats.total_time
            self.metrics.modeled_restore_stall += stats.stall_time
        return len(faults)

    # ------------------------------------------------------------------ #
    def frames_of(self, pids: Sequence[Optional[int]]) -> np.ndarray:
        """Physical frame per page id (ZERO_FRAME for unallocated slots).
        All pages must be hot (call ensure_hot first)."""
        out = np.full((len(pids),), ZERO_FRAME, np.int32)
        for i, pid in enumerate(pids):
            if pid is None:
                continue
            meta = self.pages[pid]
            if meta.pending_read:           # first read since restore:
                meta.pending_read = False   # the preload was useful
                self.metrics.useful_preloads += 1
            if self.trace is not None or self.tracer.enabled:
                self._emit(EventKind.READ, pid=pid, frame=meta.frame)
            frame = meta.frame
            assert frame is not None, f"page {pid} is cold at gather time"
            out[i] = frame
        return out

    def write_page(self, pid: int, rows: jnp.ndarray, n_valid: int) -> None:
        """Fill (a prefix of) one hot page with packed KV rows."""
        meta = self.pages[pid]
        # the event precedes the scatter so a write to a cold page is in
        # the trace even if the scatter itself corrupts the store
        self._emit(EventKind.WRITE_PAGE, pid=pid, frame=meta.frame,
                   n_valid=n_valid)
        P = self.cfg.page_tokens
        pad = P - n_valid
        if pad:
            rows = jnp.pad(rows[:n_valid], ((0, pad), (0, 0)))
        self._write_frame(meta.frame, rows)
        self.metrics.bytes_hot_written += self.page_bytes

    def write_rows(self, frames: np.ndarray, offsets: np.ndarray,
                   rows: jnp.ndarray) -> None:
        """Eagerly commit one packed row per slot into (frame, offset)
        positions — the out-of-kernel half of the KVStoreLayout commit
        contract (`commit_token`). Inactive slots should point at
        TRASH_FRAME."""
        # the event precedes validation so a zero-frame write reaches the
        # sanitizer trace even though the assert stops the scatter
        self._emit(EventKind.WRITE_ROWS,
                   frames=tuple(int(f) for f in frames))
        # validate BEFORE the scatter: the reserved zero frame backs every
        # unallocated page-table slot and must stay all-zeros
        assert ZERO_FRAME not in frames.tolist(), "write to the zero frame"
        live = sum(1 for f in frames.tolist() if f != TRASH_FRAME)
        self.metrics.bytes_hot_written += live * self.row_bytes
        self._scatter_rows(frames, offsets, rows)

    def note_fused_commit(self, frames: np.ndarray,
                          offsets: np.ndarray) -> None:
        """Account a FUSED commit: the sweep kernel's epilogue scatters the
        current token's rows into the planes in-kernel (one write per
        layer), so no host-side scatter runs — only validation, byte
        accounting, and the lifecycle trace happen here. Call BEFORE the
        kernel so the events precede the writes they describe (the same
        order `write_rows` guarantees), and so a zero-frame table stops
        the step before the kernel touches the reserved frame."""
        del offsets  # positions are per-layer-identical; frames identify pages
        for layer in range(self.n_layers):
            self._emit(EventKind.WRITE_ROWS, layer=layer,
                       frames=tuple(int(f) for f in frames))
        assert ZERO_FRAME not in frames.tolist(), "write to the zero frame"
        live = sum(1 for f in frames.tolist() if f != TRASH_FRAME)
        self.metrics.bytes_hot_written += live * self.row_bytes

"""Paged KV cache managed by the PUL engine.

The serving-side realization of the paper's tiered-memory model: KV state is
split into fixed-size *pages* of ``page_tokens`` tokens (tile-aligned per
``core.pul.TPU_SUBLANE``), living in a pool of physical frames split across

  * a **hot tier** — the fast memory the decode kernels read (HBM on TPU;
    a jnp array here), bounded at ``hot_frames`` pages, and
  * a **cold tier** — the slow memory (host DRAM / remote HBM; a numpy dict
    here) that evicted pages spill to, with real data movement both ways.

Eviction emits UNLOAD descriptors and restore emits PRELOAD descriptors
(`core.pul.TransferRequest`); restores are *planned*: `core.planner`
derives the preload distance d* = ceil(T_io / T_c) from page transfer time
vs per-page decode compute, and the restore batch is replayed through the
discrete-event twin (`core.dma`) so the engine reports how much restore
latency the schedule hides — the paper's claim, measured per serving step.

Page *contents* pack every attention layer's K and V for a token range into
one row (`PackedKVLayout`), so one logical page id covers the whole model
and a prefix page can be shared by every request with that prompt prefix
(refcounted; only full, immutable prompt pages are shared).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.events import EventKind, TraceLog
from repro.configs.base import ModelConfig
from repro.obs.tracer import NULL_TRACER
from repro.core.dma import DMAEngine, KVPageWorkload, run_kv_page_workload
from repro.core.planner import kv_page_flops, plan_kv_page_stream
from repro.core.pul import (
    Direction,
    MemoryTier,
    PEModel,
    HBM,
    REMOTE_HBM,
    TPU_SUBLANE,
    TPU_V5E_VPU,
    TransferRequest,
)

# kv-bearing cache leaves (dict key -> leaf is pageable when its seq axis
# matches max_seq): standard GQA attention and MLA's compressed cache
_KV_LEAF_KEYS = ("k", "v", "c_kv", "k_rope")


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(getattr(p, "key", str(p)) for p in path)


@dataclasses.dataclass(frozen=True)
class _LeafEntry:
    keys: Tuple[str, ...]       # dict path into the cache tree
    shape: Tuple[int, ...]      # full leaf shape
    grouped: bool               # True: (G, B, S, feat...); False: (B, S, feat...)
    nfeat: int                  # packed per-token features of this leaf
    offset: int                 # column offset in the packed row


class PackedKVLayout:
    """Mapping between a model's cache tree and packed (B, S, F) KV rows.

    Token t of slot b occupies row (b, t): the concatenation over every
    pageable cache leaf of that token's features (all layers, all kv heads).
    `pack`/`unpack` are pure jnp functions (jit-able, shape-polymorphic in
    S so prefill buckets and the decode max_seq share one layout).
    """

    def __init__(self, cfg: ModelConfig, batch: int, max_seq: int):
        from repro.models import transformer as T
        spec, _ = T.cache_specs(cfg, batch, max_seq)
        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self.entries: List[_LeafEntry] = []
        off = 0
        flat, _ = jax.tree_util.tree_flatten_with_path(spec)
        for path, leaf in sorted(flat, key=lambda kv: _path_keys(kv[0])):
            keys = _path_keys(path)
            if keys[-1] not in _KV_LEAF_KEYS:
                continue
            grouped = keys[0] == "groups"
            seq_ax = 2 if grouped else 1
            if len(leaf.shape) <= seq_ax or leaf.shape[seq_ax] != max_seq:
                continue
            nfeat = int(np.prod(leaf.shape)) // (batch * max_seq)
            self.entries.append(_LeafEntry(keys, tuple(leaf.shape), grouped,
                                           nfeat, off))
            off += nfeat
        self.features = off

    # ------------------------------------------------------------------ #
    def _get(self, tree: Any, keys: Tuple[str, ...]) -> Any:
        node = tree
        for k in keys:
            node = node[k]
        return node

    def _leaf_rows(self, leaf: jnp.ndarray, e: _LeafEntry) -> jnp.ndarray:
        """(B, S, nfeat) view of one cache leaf."""
        if e.grouped:                       # (G, B, S, feat...) -> (B, S, -1)
            G, B, S = leaf.shape[:3]
            x = jnp.moveaxis(leaf, 0, 2)    # (B, S, G, feat...)
            return x.reshape(B, S, -1)
        B, S = leaf.shape[:2]
        return leaf.reshape(B, S, -1)

    def pack(self, tree: Any) -> jnp.ndarray:
        """Cache tree -> (B, S, F) packed KV rows (S = tree's seq size)."""
        return jnp.concatenate(
            [self._leaf_rows(self._get(tree, e.keys), e)
             for e in self.entries], axis=-1)

    def pack_rows(self, tree: Any, idx: jnp.ndarray) -> jnp.ndarray:
        """One row per slot: (B, F) at per-slot positions `idx` (B,)."""
        B = idx.shape[0]
        rows = jnp.arange(B)
        outs = []
        for e in self.entries:
            leaf = self._get(tree, e.keys)
            S = leaf.shape[2 if e.grouped else 1]
            i = jnp.clip(idx, 0, S - 1)
            if e.grouped:
                x = jnp.moveaxis(leaf, 0, 2)        # (B, S, G, feat...)
                outs.append(x[rows, i].reshape(B, -1))
            else:
                outs.append(leaf[rows, i].reshape(B, -1))
        return jnp.concatenate(outs, axis=-1)

    def pack_new_rows(self, tree: Any) -> jnp.ndarray:
        """Pack a paged-decode output tree's NEW-TOKEN rows into (B, F).

        `tree` is the tree returned by the kernel-true paged decode: every
        pageable leaf holds only the current token's features — grouped
        (G, B, feat...) or ungrouped (B, feat...) — in the same entry order
        as `pack`, so the result scatters straight into tail pages."""
        outs = []
        for e in self.entries:
            leaf = self._get(tree, e.keys)
            if e.grouped:
                B = leaf.shape[1]
                outs.append(jnp.moveaxis(leaf, 0, 1).reshape(B, -1))
            else:
                outs.append(leaf.reshape(leaf.shape[0], -1))
        return jnp.concatenate(outs, axis=-1)

    def page_views(self, tree: Any, store: jnp.ndarray) -> Any:
        """Return `tree` with every pageable leaf replaced by a kernel-
        addressable view of the physical page `store` ((NP, P, F)).

        Attention leaves ((..., S, K, hd) dense) become (..., NP, K, P, hd)
        page frames — the layout `pul_paged_decode_attention` consumes; MLA
        leaves ((..., S, kvr) head-shared) become (..., NP, P, kvr) for
        `pul_paged_mla_decode_attention`. Grouped entries keep their leading
        scan axis. Non-pageable leaves (SSM state, idx) pass through."""
        NP, P, _ = store.shape
        new = jax.tree_util.tree_map(lambda x: x, tree)
        for e in self.entries:
            cols = store[:, :, e.offset:e.offset + e.nfeat]   # (NP, P, nfeat)
            feat = e.shape[3:] if e.grouped else e.shape[2:]
            if e.grouped:
                G = e.shape[0]
                view = jnp.moveaxis(cols.reshape(NP, P, G, *feat), 2, 0)
            else:
                view = cols.reshape(NP, P, *feat)
            if len(feat) == 2:              # (K, hd) -> pages (.., NP, K, P, hd)
                view = jnp.swapaxes(view, -3, -2)
            node = new
            for k in e.keys[:-1]:
                node = node[k]
            node[e.keys[-1]] = view
        return new

    def unpack_into(self, tree: Any, packed: jnp.ndarray) -> Any:
        """Return `tree` with every pageable leaf replaced from `packed`
        ((B, S, F)); non-pageable leaves (SSM states, idx) pass through."""
        B, S, _ = packed.shape
        # tree_map rebuilds every container, so in-place edits below only
        # touch the fresh copy, never the caller's tree
        new = jax.tree_util.tree_map(lambda x: x, tree)
        for e in self.entries:
            cols = packed[..., e.offset:e.offset + e.nfeat]
            if e.grouped:
                G = e.shape[0]
                feat = e.shape[3:]
                leaf = jnp.moveaxis(cols.reshape(B, S, G, *feat), 2, 0)
            else:
                leaf = cols.reshape(B, S, *e.shape[2:])
            node = new
            for k in e.keys[:-1]:
                node = node[k]
            node[e.keys[-1]] = leaf.astype(self._get(tree, e.keys).dtype)
        return new


# -------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PageConfig:
    """Knobs of the paged-KV pool (the serving face of PULConfig)."""

    page_tokens: int = 16               # tokens per page, TPU_SUBLANE-aligned
    hot_frames: int = 0                 # 0 -> sized to fit every live slot
    fast_tier: MemoryTier = HBM
    slow_tier: MemoryTier = REMOTE_HBM
    pe: PEModel = TPU_V5E_VPU
    preload_distance: Optional[int] = None   # None -> planner d*
    fifo_depth: int = 64
    share_prefix_pages: bool = True
    trace: bool = False             # record page-lifecycle events for the
                                    # sanitizer (repro.analysis); off = the
                                    # pool never touches the trace path, so
                                    # production pays zero overhead

    def __post_init__(self):
        if self.page_tokens % TPU_SUBLANE != 0:
            raise ValueError(
                f"page_tokens ({self.page_tokens}) must be a multiple of "
                f"TPU_SUBLANE ({TPU_SUBLANE}) to keep page DMAs tile-aligned")


@dataclasses.dataclass
class PoolMetrics:
    page_faults: int = 0        # pages restored from the cold tier
    evictions: int = 0          # pages written out to the cold tier
    shared_hits: int = 0        # prompt pages reused via prefix sharing
    pages_allocated: int = 0
    modeled_restore_time: float = 0.0   # DMA-twin time of all restore batches
    modeled_restore_stall: float = 0.0  # PE stall within those batches
    # cache-economics counters (repro.obs.metrics.cache_economics):
    bytes_hot_written: int = 0  # bytes scattered into the hot store (prefill
                                # page fills + decode row writes)
    # prefetch-quality counters for planned d* restores (accuracy /
    # timeliness / coverage, per the prefetching survey in PAPERS.md):
    planned_preloads: int = 0   # restores issued through ensure_hot's
                                # planned d* batch
    unplanned_restores: int = 0  # demand restores outside a planned batch
                                # (none today; exists so a speculative
                                # planner's misses become visible)
    useful_preloads: int = 0    # restored pages read before re-eviction
    wasted_preloads: int = 0    # restored pages evicted/freed unread
    descriptors: List[TransferRequest] = dataclasses.field(default_factory=list)

    @property
    def modeled_latency_hidden(self) -> float:
        """Fraction of restore wall-time the planned preload overlapped."""
        if self.modeled_restore_time <= 0:
            return 1.0
        return 1.0 - self.modeled_restore_stall / self.modeled_restore_time

    def validate(self) -> None:
        """Cross-check the counters' arithmetic invariants; raises
        ValueError naming the broken one. Called from the engine's metrics
        hook so a drifted counter surfaces at the snapshot that drifted,
        not in a downstream report."""
        for name in ("page_faults", "evictions", "shared_hits",
                     "pages_allocated", "bytes_hot_written",
                     "planned_preloads", "unplanned_restores",
                     "useful_preloads", "wasted_preloads"):
            v = getattr(self, name)
            if v < 0:
                raise ValueError(f"PoolMetrics.{name} is negative ({v})")
        if (self.useful_preloads + self.wasted_preloads
                > self.planned_preloads + self.unplanned_restores):
            raise ValueError(
                "PoolMetrics: more preload outcomes (useful + wasted) than "
                "restores issued")
        if self.modeled_restore_time < 0 or self.modeled_restore_stall < 0:
            raise ValueError("PoolMetrics modeled restore times are negative")
        # every restore re-loads a page that previously spilled: the planned
        # preloads (PRELOAD descriptors) must pair 1:1 with page faults, and
        # can never outnumber the evictions that created cold copies
        preloads = sum(1 for d in self.descriptors
                       if d.direction is Direction.PRELOAD)
        unloads = sum(1 for d in self.descriptors
                      if d.direction is Direction.UNLOAD)
        if preloads != self.page_faults:
            raise ValueError(
                f"PoolMetrics: {preloads} PRELOAD descriptors but "
                f"{self.page_faults} page faults (restores must be planned)")
        if unloads != self.evictions:
            raise ValueError(
                f"PoolMetrics: {unloads} UNLOAD descriptors but "
                f"{self.evictions} evictions")
        if self.page_faults > self.evictions:
            raise ValueError(
                f"PoolMetrics: {self.page_faults} restores exceed "
                f"{self.evictions} evictions — a page was restored that "
                "never spilled")
        hidden = self.modeled_latency_hidden
        if not 0.0 <= hidden <= 1.0:
            raise ValueError(
                f"PoolMetrics.modeled_latency_hidden = {hidden} out of "
                "[0, 1]")


@dataclasses.dataclass
class _PageMeta:
    frame: Optional[int]        # hot frame index, or None when cold
    refcount: int = 1
    last_used: int = 0
    shared_key: Optional[tuple] = None
    deadline: float = float("inf")   # owning request's TTFT deadline tick
                                     # (inf: none) — eviction prefers pages
                                     # whose requests can afford the restore
    pending_read: bool = False  # restored but not yet read: cleared at first
                                # READ (a useful preload), still set at the
                                # next evict/free (a wasted one) — the
                                # prefetch-accuracy bookkeeping


ZERO_FRAME = 0      # reserved all-zeros frame (unallocated page-table slots)
TRASH_FRAME = 1     # reserved write sink (inactive slots' decode writes)
RESERVED_FRAMES = 2


class KVPagePool:
    """Physical page frames + residency + refcounts + tier movement."""

    def __init__(self, pcfg: PageConfig, features: int, *,
                 gqa_group: int = 1, dtype=jnp.bfloat16, tracer=None):
        self.cfg = pcfg
        self.features = features
        self.dtype = dtype
        P = pcfg.page_tokens
        self.page_bytes = P * features * jnp.dtype(dtype).itemsize
        self.row_bytes = features * jnp.dtype(dtype).itemsize
        n = max(pcfg.hot_frames, RESERVED_FRAMES + 1)
        self.store = jnp.zeros((n, P, features), dtype)
        self.free_frames: List[int] = list(range(RESERVED_FRAMES, n))
        self.pages: "OrderedDict[int, _PageMeta]" = OrderedDict()
        self.cold: Dict[int, np.ndarray] = {}
        self.prefix_index: Dict[tuple, int] = {}
        self.metrics = PoolMetrics()
        # lifecycle event trace for the sanitizer (repro.analysis); None
        # when tracing is off — every emission site guards on this, so the
        # untraced hot path never builds an event
        self.trace: Optional[TraceLog] = TraceLog() if pcfg.trace else None
        # unified tracer (repro.obs): page-lifecycle events are bridged into
        # the same stream as engine spans and DMA descriptors; NULL_TRACER
        # keeps every emission site a cheap attribute check when off
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._bridge_seq = 0    # event sequence when TraceLog is off
        self._next_id = 1
        self._clock = 0
        # restore planning: d* from page transfer time vs per-page compute
        self.plan = plan_kv_page_stream(
            page_tokens=P, kv_features=features, tier=pcfg.slow_tier,
            pe=pcfg.pe, gqa_group=gqa_group, fifo_depth=pcfg.fifo_depth,
            itemsize=jnp.dtype(dtype).itemsize)
        self.distance = pcfg.preload_distance or self.plan.cfg.distance
        self._dma = DMAEngine(pcfg.slow_tier, pcfg.pe,
                              fifo_depth=pcfg.fifo_depth,
                              tracer=self.tracer)
        self._flops_per_page = kv_page_flops(P, features, gqa_group)

    # ------------------------------------------------------------------ #
    @property
    def hot_frames(self) -> int:
        return self.store.shape[0]

    @property
    def capacity(self) -> int:
        """Usable hot frames (page working set must fit here per step)."""
        return self.hot_frames - RESERVED_FRAMES

    def hot_in_use(self) -> int:
        return sum(1 for m in self.pages.values() if m.frame is not None)

    # ------------------------------------------------------------------ #
    def _emit(self, kind: EventKind, **fields) -> None:
        if self.trace is not None:
            self.trace.emit(self._clock, kind, **fields)
        if self.tracer.enabled:
            seq = (self.trace.events[-1].seq if self.trace is not None
                   else self._bridge_seq)
            self._bridge_seq = seq + 1
            self.tracer.page_event(seq, self._clock, kind, fields)

    def tick(self) -> None:
        self._clock += 1
        self._emit(EventKind.TICK)

    def alloc(self, shared_key: Optional[tuple] = None, *,
              needed: Sequence[int] = ()) -> int:
        """Allocate a fresh page in the hot tier; returns its page id.

        `needed` is the caller's CURRENT working set (page ids the ongoing
        step still has to read): frame stealing will never evict them, so an
        allocation can't trigger a same-step fault/restore round-trip."""
        pid = self._next_id
        self._next_id += 1
        frame = self._take_frame(needed=needed)
        self.pages[pid] = _PageMeta(frame=frame, last_used=self._clock,
                                    shared_key=shared_key)
        if shared_key is not None:
            self.prefix_index[shared_key] = pid
        self.metrics.pages_allocated += 1
        self._emit(EventKind.ALLOC, pid=pid, frame=frame, refcount=1,
                   shared_key=shared_key)
        return pid

    def lookup_shared(self, key: tuple) -> Optional[int]:
        if not self.cfg.share_prefix_pages:
            return None
        pid = self.prefix_index.get(key)
        if pid is not None:
            self.pages[pid].refcount += 1
            self.metrics.shared_hits += 1
            self._emit(EventKind.REF, pid=pid,
                       refcount=self.pages[pid].refcount, shared_key=key)
        return pid

    def ref(self, pid: int) -> None:
        self.pages[pid].refcount += 1
        self._emit(EventKind.REF, pid=pid, refcount=self.pages[pid].refcount)

    def unref(self, pid: int) -> None:
        meta = self.pages[pid]
        meta.refcount -= 1
        self._emit(EventKind.UNREF, pid=pid, refcount=meta.refcount)
        if meta.refcount > 0:
            return
        if meta.pending_read:               # freed without ever being read
            meta.pending_read = False
            self.metrics.wasted_preloads += 1
        if meta.shared_key is not None:
            self.prefix_index.pop(meta.shared_key, None)
        if meta.frame is not None:
            self.free_frames.append(meta.frame)
        self.cold.pop(pid, None)
        del self.pages[pid]
        self._emit(EventKind.FREE, pid=pid)

    # ------------------------------------------------------------------ #
    def note_deadline(self, pids: Sequence[int], deadline: float) -> None:
        """Tag pages with their owning request's absolute TTFT-deadline
        tick (inf: no deadline). Eviction orders victims by LATEST deadline
        first — a page whose request has slack can afford the restore
        round-trip; one racing a deadline cannot. The engine refreshes tags
        at every admission/resume, so a shared page carries its most recent
        requester's urgency (a deliberate, cheap approximation)."""
        for pid in pids:
            self.pages[pid].deadline = deadline
            self._emit(EventKind.DEADLINE, pid=pid, deadline=deadline)

    def _take_frame(self, needed: Sequence[int]) -> int:
        """Get a free hot frame, evicting pages not in `needed` — latest
        request deadline first (deadline-aware), then LRU within a tie."""
        if self.free_frames:
            return self.free_frames.pop()
        needed = set(needed)
        victims = sorted(
            ((-m.deadline, m.last_used), pid) for pid, m in self.pages.items()
            if m.frame is not None and pid not in needed)
        if not victims:
            raise RuntimeError(
                f"hot tier exhausted: {self.capacity} frames all needed this "
                "step; raise PageConfig.hot_frames or admit fewer tokens")
        _, victim = victims[0]
        self.evict(victim, cause="steal", pinned=needed)
        return self.free_frames.pop()

    def evict(self, pid: int, *, cause: str = "explicit",
              pinned: Sequence[int] = ()) -> None:
        """Hot -> cold: real data movement + an UNLOAD descriptor.

        `cause` is sanitizer provenance: "steal" marks capacity evictions
        (which must follow the deadline-then-LRU victim order over the
        non-`pinned` hot pages); "explicit" marks policy-driven spills
        (preemption, pause) that are exempt from victim-order checks."""
        meta = self.pages[pid]
        assert meta.frame is not None, f"page {pid} already cold"
        if meta.pending_read:               # restored but never read before
            meta.pending_read = False       # spilling again: wasted preload
            self.metrics.wasted_preloads += 1
        self._emit(EventKind.EVICT, pid=pid, frame=meta.frame, cause=cause,
                   pinned=tuple(sorted(pinned)))
        self.cold[pid] = np.asarray(self.store[meta.frame])
        self.free_frames.append(meta.frame)
        self.metrics.evictions += 1
        self.metrics.descriptors.append(TransferRequest(
            Direction.UNLOAD, src=meta.frame * self.page_bytes,
            dst=pid * self.page_bytes, nbytes=self.page_bytes, tag=pid))
        meta.frame = None

    def evict_pages(self, pids: Sequence[int]) -> None:
        for pid in pids:
            if self.pages[pid].frame is not None:
                self.evict(pid)

    def ensure_hot(self, pids: Sequence[int]) -> int:
        """Restore any cold page in `pids`; returns the page-fault count.

        Restores are issued as one planned batch: preload distance d* (from
        `core.planner`), BATCH issue order, and the batch is replayed on the
        DMA twin to account the modeled stall (the per-step page-fault cost
        a TPU deployment would see).
        """
        self.tick()
        faults = []
        for pid in pids:
            meta = self.pages[pid]
            meta.last_used = self._clock
            self._emit(EventKind.TOUCH, pid=pid)
            if meta.frame is None:
                faults.append(pid)
        for pid in faults:
            meta = self.pages[pid]
            frame = self._take_frame(needed=pids)
            data = self.cold.pop(pid)
            self.store = self.store.at[frame].set(jnp.asarray(data))
            meta.frame = frame
            meta.pending_read = True
            self._emit(EventKind.RESTORE, pid=pid, frame=frame)
            self.metrics.descriptors.append(TransferRequest(
                Direction.PRELOAD, src=pid * self.page_bytes,
                dst=frame * self.page_bytes, nbytes=self.page_bytes, tag=pid))
        if faults:
            self.metrics.page_faults += len(faults)
            self.metrics.planned_preloads += len(faults)
            stats = run_kv_page_workload(
                self._dma,
                KVPageWorkload(page_bytes=self.page_bytes,
                               flops_per_page=self._flops_per_page,
                               pages_per_step=len(faults), steps=1),
                distance=self.distance)
            self.metrics.modeled_restore_time += stats.total_time
            self.metrics.modeled_restore_stall += stats.stall_time
        return len(faults)

    # ------------------------------------------------------------------ #
    def frames_of(self, pids: Sequence[Optional[int]]) -> np.ndarray:
        """Physical frame per page id (ZERO_FRAME for unallocated slots).
        All pages must be hot (call ensure_hot first)."""
        out = np.full((len(pids),), ZERO_FRAME, np.int32)
        for i, pid in enumerate(pids):
            if pid is None:
                continue
            meta = self.pages[pid]
            if meta.pending_read:           # first read since restore:
                meta.pending_read = False   # the preload was useful
                self.metrics.useful_preloads += 1
            if self.trace is not None or self.tracer.enabled:
                self._emit(EventKind.READ, pid=pid, frame=meta.frame)
            frame = meta.frame
            assert frame is not None, f"page {pid} is cold at gather time"
            out[i] = frame
        return out

    def write_page(self, pid: int, rows: jnp.ndarray, n_valid: int) -> None:
        """Fill (a prefix of) one hot page with packed KV rows."""
        meta = self.pages[pid]
        # the event precedes the scatter so a write to a cold page is in
        # the trace even if the scatter itself corrupts the store
        self._emit(EventKind.WRITE_PAGE, pid=pid, frame=meta.frame,
                   n_valid=n_valid)
        P = self.cfg.page_tokens
        pad = P - n_valid
        if pad:
            rows = jnp.pad(rows[:n_valid], ((0, pad), (0, 0)))
        self.store = self.store.at[meta.frame].set(rows.astype(self.dtype))
        self.metrics.bytes_hot_written += self.page_bytes

    def write_rows(self, frames: np.ndarray, offsets: np.ndarray,
                   rows: jnp.ndarray) -> None:
        """Scatter one packed row per slot into (frame, offset) positions.
        Inactive slots should point at TRASH_FRAME."""
        # the event precedes validation so a zero-frame write reaches the
        # sanitizer trace even though the assert stops the scatter
        self._emit(EventKind.WRITE_ROWS,
                   frames=tuple(int(f) for f in frames))
        # validate BEFORE the scatter: the reserved zero frame backs every
        # unallocated page-table slot and must stay all-zeros
        assert ZERO_FRAME not in frames.tolist(), "write to the zero frame"
        live = sum(1 for f in frames.tolist() if f != TRASH_FRAME)
        self.metrics.bytes_hot_written += live * self.row_bytes
        self.store = self.store.at[
            jnp.asarray(frames), jnp.asarray(offsets)].set(
                rows.astype(self.dtype))

from repro.serving.engine import (
    EngineConfig,
    EngineMetrics,
    PagedEngineConfig,
    PagedServingEngine,
    Request,
    ServingEngine,
    mean,
    percentile,
)
from repro.serving.kv_pages import (KVPagePool, PackedKVLayout,
                                    PageConfig, PoolMetrics)
from repro.serving.scheduler import (
    POLICIES,
    AdmissionScheduler,
    SchedulerConfig,
)

__all__ = [
    "EngineConfig", "Request", "ServingEngine",
    "PagedEngineConfig", "PagedServingEngine", "EngineMetrics",
    "KVPagePool", "PackedKVLayout", "PageConfig", "PoolMetrics",
    "AdmissionScheduler", "SchedulerConfig", "POLICIES",
    "percentile", "mean",
]

from repro.serving.config import ServingConfig
from repro.serving.engine import (
    EngineConfig,
    EngineMetrics,
    PagedEngineConfig,
    PagedServingEngine,
    Request,
    ServingEngine,
    mean,
    percentile,
)
from repro.serving.kv_pages import (KV_LAYOUT_VERSION, KVPagePool,
                                    KVStoreLayout, PackedKVLayout,
                                    PageConfig, PoolMetrics)
from repro.serving.scheduler import (
    POLICIES,
    AdmissionScheduler,
    SchedulerConfig,
)

__all__ = [
    "ServingConfig",
    "EngineConfig", "Request", "ServingEngine",
    "PagedEngineConfig", "PagedServingEngine", "EngineMetrics",
    "KVPagePool", "KVStoreLayout", "KV_LAYOUT_VERSION", "PackedKVLayout",
    "PageConfig", "PoolMetrics",
    "AdmissionScheduler", "SchedulerConfig", "POLICIES",
    "percentile", "mean",
]

"""Batched serving engine: slot scheduler + prefill/decode over the zoo.

Continuous-batching-lite: a fixed pool of B slots, each holding one request's
progress; finished slots are refilled from the queue between decode steps.
Per-slot state lives inside the *batched* KV caches (cache idx is per-slot
via attention masks keyed on pos0). Prefill pads prompts to a bucket so one
compiled prefill_step serves many lengths.

The decode loop is the serving face of PUL: caches stream through the
pul_attention/pul_gather kernels on TPU; the engine itself never re-compiles
once warmed (fixed shapes), which is what lets the slot scheduler interleave
arbitrary request mixes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import zoo


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    batch_slots: int = 4
    max_seq: int = 256
    prefill_bucket: int = 64
    greedy: bool = True


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig = EngineConfig()):
        self.model_cfg = cfg
        self.cfg = engine_cfg
        self.model = zoo.build_model(cfg)
        self.params = params
        B, S = engine_cfg.batch_slots, engine_cfg.max_seq
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_seq=S))
        self._decode = jax.jit(self.model.decode_step)
        self.caches = None
        self.slot_req: List[Optional[Request]] = [None] * B
        self.slot_pos: np.ndarray = np.zeros((B,), np.int32)  # next position
        self.queue: List[Request] = []

    # ------------------------------------------------------------------ #
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Fill free slots; (re)prefill the whole batch when admitting.

        A production engine prefills only new slots with per-slot cache
        writes; to keep one compiled path we re-prefill the batch — same
        results, admission just costs a batch prefill (documented trade)."""
        free = self._free_slots()
        if not free or not self.queue:
            return
        while free and self.queue:
            self.slot_req[free.pop(0)] = self.queue.pop(0)
        self._prefill_all()

    def _prefill_all(self):
        B, bucket = self.cfg.batch_slots, self.cfg.prefill_bucket
        toks = np.zeros((B, bucket), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            prompt = (r.prompt + r.out_tokens)[-bucket:]
            toks[i, -len(prompt):] = prompt       # left-pad
            self.slot_pos[i] = bucket
        batch = {"tokens": jnp.asarray(toks)}
        logits, caches = self._prefill(self.params, batch)
        self.caches = caches
        self._emit(np.asarray(logits))

    def _emit(self, logits: np.ndarray):
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            nxt = int(np.argmax(logits[i])) if self.cfg.greedy else int(
                np.random.default_rng(0).choice(logits.shape[-1]))
            r.out_tokens.append(nxt)
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                self.slot_req[i] = None

    # ------------------------------------------------------------------ #
    def step(self):
        """One engine tick: admit + one decode step for all live slots."""
        self._admit()
        if self.caches is None or all(r is None for r in self.slot_req):
            return
        B = self.cfg.batch_slots
        toks = np.zeros((B, 1), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None and r.out_tokens:
                toks[i, 0] = r.out_tokens[-1]
        batch = {"tokens": jnp.asarray(toks),
                 "pos0": jnp.asarray(self.slot_pos)}
        logits, self.caches = self._decode(self.params, batch, self.caches)
        self.slot_pos = self.slot_pos + 1
        self._emit(np.asarray(logits))

    def run(self, max_ticks: int = 1000) -> Dict[int, List[int]]:
        done: Dict[int, List[int]] = {}
        ticks = 0
        pending = lambda: self.queue or any(r is not None for r in self.slot_req)
        submitted = {r.rid: r for r in self.queue}
        while pending() and ticks < max_ticks:
            self.step()
            ticks += 1
        for rid, r in submitted.items():
            done[rid] = r.out_tokens
        return done

"""Serving engines: dense reference + paged, PUL-tiered continuous batching.

Two engines share the zoo's prefill/decode entry points:

  * :class:`ServingEngine` — the dense-cache reference ("continuous-
    batching-lite"): a fixed pool of B slots over monolithic per-slot KV
    that never leaves fast memory; admission re-prefills the batch. Kept as
    the differential-test oracle and as the simplest serving path.

  * :class:`PagedServingEngine` — the production-shaped engine this repo
    exists to showcase: KV lives in fixed-size pages managed by the PUL
    page pool (`serving.kv_pages`), requests are admitted by a token-budget
    scheduler (`serving.scheduler`), slots refill per step without touching
    their neighbours (per-slot cache fill levels), same-bucket requests
    sharing a page-aligned prompt prefix share prompt pages, and cold pages
    ride UNLOAD/PRELOAD descriptors planned at the paper's d* distance.

Decode runs one of two equivalent paths:

  * **assembly** (default): each step's dense cache view is rebuilt from
    pages (token r of slot b == packed row r) — optionally through the
    page-indexed PUL gather (``use_pallas_gather=True``) — then decoded as
    usual; greedy token streams match the dense reference bit-for-bit, the
    invariant `tests/test_paged_serving.py` enforces. Kept as the oracle.
  * **kernel-true** (``use_paged_kernel=True``): attention streams straight
    over the page frames (`kernels.pul_paged_decode_attention`, or the MLA
    variant over compressed pages), the page table acting as the preload
    trace; the current token's K/V merges into the online softmax in-kernel
    and is scattered into its tail page afterwards. No dense per-slot view
    is ever materialized — the serving realization of the paper's claim.

Fully-shared prompts are cheaper still: when a request's whole page-aligned
prompt already lives in shared pages, admission refs the pages and replays
the cached first-token logits — zero prefill compute (`prefill_skips`).

Scheduling is policy-driven (``PagedEngineConfig.policy``): ``fcfs`` is the
original strict-FIFO admission; ``priority`` and ``slo-edf`` additionally
PREEMPT running requests to make room for urgent arrivals — the victim's
private pages spill to the cold tier (the existing swap-out machinery), its
slot is vacated, and the request requeues for readmission, resuming
mid-decode from its restored pages token-for-token. ``slo-edf`` orders the
queue by TTFT deadline and preempts only when a pending deadline would
otherwise be missed (no running slot frees up in time).

Chunked prefill (``prefill_chunk_tokens > 0``): long prompts prefill in
page-aligned chunks, ONE bounded pass per engine tick, interleaved with the
decode step — a long prompt can no longer head-of-line-block every short
request's decode tick. Each pass re-runs the compiled bucket prefill over
the prompt prefix so far (the smallest bucket that fits it, so per-tick
prefill span is bounded by the prefix, not the full prompt) and banks the
new chunk's KV pages; rows are bitwise identical to a monolithic prefill
(causal attention: row t depends only on tokens <= t), so token streams
stay dense-reference-exact. The slot joins decode on the pass that
completes the prompt. On a real accelerator each pass would attend to the
banked pages instead of recomputing the prefix; the scheduling shape — and
the per-tick latency bound that protects decode — is the same.

MoE caveat: capacity-factor dispatch mixes tokens across the batch, so MoE
archs serve fine but are not bitwise batch-size-invariant; the differential
zoo subset uses dense archs.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import zoo
from repro.obs.metrics import (
    MetricsRegistry,
    cache_economics,
    economics_into_registry,
)
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.serving.kv_pages import (
    KVPagePool,
    PackedKVLayout,
    PageConfig,
    TRASH_FRAME,
    ZERO_FRAME,
    _path_keys,
)
from repro.serving.scheduler import (
    Admission,
    AdmissionScheduler,
    Request,
    SchedulerConfig,
)


def percentile(xs, q: float) -> float:
    """q-th percentile (linear interpolation) as a plain float.

    Degenerate inputs are first-class: an EMPTY sample returns 0.0 instead
    of raising (np.percentile([]) crashes), so a metrics snapshot taken on
    a tiny/zero-length run — exactly what the SLO benchmark's smoke config
    produces — can never take the engine down."""
    xs = list(xs)
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, np.float64), q))


def mean(xs) -> float:
    """Mean as a plain float; 0.0 for an empty sample (np.mean([]) is nan
    with a RuntimeWarning — poison for a JSON metrics report)."""
    xs = list(xs)
    if not xs:
        return 0.0
    return float(np.mean(np.asarray(xs, np.float64)))


def _drain_results(requests: Dict[int, Request]) -> Dict[int, List[int]]:
    """Collect every tracked request's output and prune the completed ones
    (a long-lived engine must not accumulate historical requests)."""
    out = {rid: r.out_tokens for rid, r in requests.items()}
    for rid in [rid for rid, r in requests.items() if r.done]:
        del requests[rid]
    return out


# ========================================================================== #
# dense reference engine
# ========================================================================== #
@dataclasses.dataclass(frozen=True)
class EngineConfig:
    batch_slots: int = 4
    max_seq: int = 256
    prefill_bucket: int = 64
    greedy: bool = True
    sample_seed: int = 0            # rng seed for greedy=False sampling
                                    # (mirrors PagedEngineConfig.sample_seed
                                    # so sampling runs are differential-
                                    # testable across the two engines)


class ServingEngine:
    """Dense-cache slot engine (left-padded bucket prefill, batch re-prefill
    on admission). The differential-test oracle for the paged engine."""

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig = EngineConfig()):
        from repro.serving.config import ServingConfig
        if isinstance(engine_cfg, ServingConfig):
            engine_cfg = engine_cfg.dense()
        self.model_cfg = cfg
        self.cfg = engine_cfg
        self.model = zoo.build_model(cfg)
        self.params = params
        B, S = engine_cfg.batch_slots, engine_cfg.max_seq
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_seq=S))
        self._decode = jax.jit(self.model.decode_step)
        self.caches = None
        self.slot_req: List[Optional[Request]] = [None] * B
        self.slot_pos: np.ndarray = np.zeros((B,), np.int32)  # next position
        self.queue: List[Request] = []
        self.requests: Dict[int, Request] = {}   # every request ever submitted
        self._rng = np.random.default_rng(engine_cfg.sample_seed)

    # ------------------------------------------------------------------ #
    def submit(self, req: Request):
        self.requests[req.rid] = req
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Fill free slots; (re)prefill the whole batch when admitting.

        A production engine prefills only new slots with per-slot cache
        writes (see PagedServingEngine); to keep one compiled path we
        re-prefill the batch — same results, admission just costs a batch
        prefill (documented trade)."""
        free = self._free_slots()
        if not free or not self.queue:
            return
        while free and self.queue:
            self.slot_req[free.pop(0)] = self.queue.pop(0)
        self._prefill_all()

    def _prefill_all(self):
        B, bucket = self.cfg.batch_slots, self.cfg.prefill_bucket
        toks = np.zeros((B, bucket), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            prompt = (r.prompt + r.out_tokens)[-bucket:]
            toks[i, -len(prompt):] = prompt       # left-pad
            self.slot_pos[i] = bucket
        batch = {"tokens": jnp.asarray(toks)}
        logits, caches = self._prefill(self.params, batch)
        self.caches = caches
        self._emit(np.asarray(logits))

    def _emit(self, logits: np.ndarray):
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            if self.cfg.greedy:
                nxt = int(np.argmax(logits[i]))
            else:
                z = logits[i].astype(np.float64) - logits[i].max()
                p = np.exp(z)
                nxt = int(self._rng.choice(p.shape[-1], p=p / p.sum()))
            r.out_tokens.append(nxt)
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                self.slot_req[i] = None

    # ------------------------------------------------------------------ #
    def step(self):
        """One engine tick: admit + one decode step for all live slots."""
        self._admit()
        if self.caches is None or all(r is None for r in self.slot_req):
            return
        B = self.cfg.batch_slots
        toks = np.zeros((B, 1), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None and r.out_tokens:
                toks[i, 0] = r.out_tokens[-1]
        batch = {"tokens": jnp.asarray(toks),
                 "pos0": jnp.asarray(self.slot_pos)}
        logits, self.caches = self._decode(self.params, batch, self.caches)
        self.slot_pos = self.slot_pos + 1
        self._emit(np.asarray(logits))

    def run(self, max_ticks: int = 1000) -> Dict[int, List[int]]:
        """Drive steps until every tracked request completes (or the tick
        cap); returns {rid: generated tokens} for ALL submitted requests —
        including those already admitted into slots before run() was called
        (a queue-only snapshot would silently drop their outputs)."""
        ticks = 0
        pending = lambda: self.queue or any(r is not None for r in self.slot_req)
        while pending() and ticks < max_ticks:
            self.step()
            ticks += 1
        return _drain_results(self.requests)


# ========================================================================== #
# paged engine
# ========================================================================== #
@dataclasses.dataclass(frozen=True)
class PagedEngineConfig:
    batch_slots: int = 4
    max_seq: int = 256
    page_tokens: int = 16
    hot_pages: int = 0              # 0 -> size for every live slot resident
    prefill_buckets: Tuple[int, ...] = (16, 32, 64)
    max_active_tokens: int = 0      # 0 -> slots * max_seq
    preload_distance: Optional[int] = None   # None -> planner d*
    share_prefix_pages: bool = True
    use_pallas_gather: bool = False  # route page assembly through pul_gather
    use_paged_kernel: bool = False   # kernel-true decode: attention streams
                                     # straight over pages (no dense assembly);
                                     # False keeps assemble-then-attend as the
                                     # oracle path
    sweep_decode: bool = True        # kernel-true decode as ONE sweep: the
                                     # layer scan walks the full per-layer
                                     # planes (zero-copy views), the sweep
                                     # kernel selects its layer via an SMEM
                                     # scalar and commits the new token's
                                     # rows in its fused epilogue. False
                                     # keeps the per-layer launch + eager
                                     # write_rows scatter (parity baseline)
    policy: str = "fcfs"            # "fcfs" | "priority" | "slo-edf"
    prefill_chunk_tokens: int = 0   # >0: prompts longer than this prefill in
                                    # page-aligned chunks, one pass per tick,
                                    # interleaved with decode (0 = monolithic
                                    # prefill at admission)
    greedy: bool = True
    sample_seed: int = 0            # rng seed for greedy=False sampling
    shadow_check: bool = False      # record the page-lifecycle trace and
                                    # replay it through the sanitizer
                                    # (repro.analysis) EVERY tick, raising
                                    # LifecycleViolationError at the tick
                                    # that broke the contract. Test-only:
                                    # off (default) => no trace, no checker,
                                    # zero hot-path overhead


@dataclasses.dataclass
class EngineMetrics:
    ticks: int = 0
    tokens_emitted: int = 0
    prefills: int = 0
    prefill_skips: int = 0      # admissions served entirely from shared pages
    chunk_passes: int = 0       # chunked-prefill passes (subset of prefills)
    decode_steps: int = 0
    preemptions: int = 0        # slots swapped out for a more urgent arrival
    readmissions: int = 0       # preempted requests resumed mid-stream
    slo_violations: int = 0     # first tokens emitted after their deadline
    wall_time: float = 0.0

    @property
    def tokens_per_sec(self) -> float:
        """Throughput; 0.0 (not a ZeroDivisionError) when no wall time has
        accumulated — snapshots are taken before the first step too."""
        if self.wall_time <= 0.0:
            return 0.0
        return self.tokens_emitted / self.wall_time


class PagedServingEngine:
    """Continuous batching over a paged, PUL-tiered KV cache."""

    def __init__(self, cfg: ModelConfig, params,
                 engine_cfg: PagedEngineConfig = PagedEngineConfig(),
                 metrics_hook: Optional[Callable[[Dict[str, Any]], None]] = None,
                 tracer: Optional[Tracer] = None):
        from repro.serving.config import ServingConfig
        if isinstance(engine_cfg, ServingConfig):
            engine_cfg = engine_cfg.paged()
        self.base_cfg = cfg
        self.model_cfg = dataclasses.replace(cfg, paged_kv=True)
        self.cfg = engine_cfg
        self.metrics_hook = metrics_hook
        # one tracer threaded through the whole stack (engine spans,
        # scheduler decisions, page lifecycle, DMA twin); NULL_TRACER (the
        # default) makes every emission site a no-op
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.model = zoo.build_model(self.model_cfg)
        self.params = params

        B, S, P = engine_cfg.batch_slots, engine_cfg.max_seq, engine_cfg.page_tokens
        if S % P:
            raise ValueError(f"max_seq ({S}) must be a multiple of "
                             f"page_tokens ({P})")
        if max(engine_cfg.prefill_buckets) > S:
            raise ValueError("prefill bucket exceeds max_seq")
        if engine_cfg.prefill_chunk_tokens and (
                engine_cfg.prefill_chunk_tokens % P):
            raise ValueError(
                f"prefill_chunk_tokens ({engine_cfg.prefill_chunk_tokens}) "
                f"must be a multiple of page_tokens ({P}) so chunk "
                "boundaries are page-aligned")
        self.n_pages_per_slot = S // P

        self.layout = PackedKVLayout(self.model_cfg, B, S)
        hot = engine_cfg.hot_pages or (B * self.n_pages_per_slot + 2)
        gqa = cfg.num_heads // max(cfg.num_kv_heads, 1)
        pcfg = PageConfig(page_tokens=P, hot_frames=hot + 2,
                          preload_distance=engine_cfg.preload_distance,
                          share_prefix_pages=engine_cfg.share_prefix_pages,
                          trace=engine_cfg.shadow_check)
        if self.layout.features:
            # v2 hot tier: per-layer planes — the arrays the sweep kernel
            # walks ARE the store, so page views under jit are zero-copy
            self.pool = KVPagePool(pcfg, layout=self.layout, gqa_group=gqa,
                                   tracer=self.tracer)
        else:
            # no pageable KV (pure-SSM archs): a vestigial packed pool keeps
            # the allocator/trace machinery alive with 1 feature column
            self.pool = KVPagePool(pcfg, 1, gqa_group=gqa,
                                   tracer=self.tracer)
        # shadow mode: an incremental lifecycle checker consumes the pool
        # trace every tick (O(new events) per tick), so a violation names
        # the offending event at the tick it happened
        self._shadow_checker = None
        if engine_cfg.shadow_check:
            from repro.analysis.sanitizer import LifecycleChecker
            self._shadow_checker = LifecycleChecker()
        self.scheduler = AdmissionScheduler(SchedulerConfig(
            prefill_buckets=engine_cfg.prefill_buckets,
            max_active_tokens=engine_cfg.max_active_tokens or B * S,
            page_tokens=P, policy=engine_cfg.policy, max_seq=S),
            tracer=self.tracer)

        # compiled entry points: one prefill per bucket, one decode; the
        # kernel-true path binds the planner's d* as the in-kernel preload
        # distance (static arg, so it is part of the compiled artifact)
        self._prefill_fns: Dict[int, Callable] = {}
        self._decode = jax.jit(self.model.decode_step)
        d = max(1, min(self.pool.distance, self.pool.cfg.fifo_depth))
        self._paged_decode = jax.jit(functools.partial(
            self.model.paged_decode_step, pul_distance=d))
        # single-sweep decode: planes ride as a donated argument so the
        # fused in-kernel commit updates them in place (no copy of the
        # store per step); returns (logits, new_tree, planes)
        self._sweep_decode = jax.jit(functools.partial(
            self.model.paged_decode_step, pul_distance=d),
            donate_argnums=(3,))

        # slot state
        self.slot_req: List[Optional[Request]] = [None] * B
        self.slot_len = np.zeros((B,), np.int32)    # tokens cached per slot
        self.slot_pages: List[List[int]] = [[] for _ in range(B)]
        self.paused: List[bool] = [False] * B
        spec, _ = self.model.cache_specs(B, S)
        self.resident = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), spec)
        self.metrics = EngineMetrics()
        self.requests: Dict[int, Request] = {}
        self._rng = np.random.default_rng(engine_cfg.sample_seed)
        self._paused_state: Dict[int, Dict[Tuple[str, ...], Any]] = {}
        # policy-preempted (swapped-out) requests: rid -> saved slot state
        # (page ids — cold until readmission —, fill level, non-pageable
        # rows, chunked-prefill progress); the request itself is requeued
        self._swapped: Dict[int, Dict[str, Any]] = {}
        # in-flight chunked prefills: slot -> {"prompt", "filled"}
        self._chunk: Dict[int, Dict[str, Any]] = {}
        self._tick = 0
        # prefill-compute reuse: first-token logits per fully page-aligned
        # shared prompt, keyed (bucket, prompt tuple); bounded LRU. Only
        # sound when no non-pageable recurrent state exists (pages rebuild
        # attention KV exactly; SSM/conv state cannot be rebuilt from pages).
        pageable = {e.keys for e in self.layout.entries}
        self._has_recurrent = any(
            _path_keys(path) not in pageable and _path_keys(path)[-1] != "idx"
            for path, _ in jax.tree_util.tree_flatten_with_path(spec)[0])
        self._prompt_logits: "OrderedDict[tuple, np.ndarray]" = OrderedDict()

    # ------------------------------------------------------------------ #
    def _prefill_for(self, bucket: int) -> Callable:
        if bucket not in self._prefill_fns:
            model = self.model
            self._prefill_fns[bucket] = jax.jit(
                lambda p, b, _bucket=bucket: model.prefill(
                    p, b, max_seq=_bucket))
        return self._prefill_fns[bucket]

    def submit(self, req: Request):
        """Reject-at-submit anything that can NEVER be served: a queue slot
        for an impossible request is a permanent head-of-line wedge."""
        cost = self.scheduler.request_cost(req)
        if cost > self.cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) = {cost} exceeds "
                f"max_seq ({self.cfg.max_seq}); it can never fit a slot")
        if self.scheduler.request_pages(req) > self.pool.capacity:
            raise ValueError(
                f"request {req.rid} needs {self.scheduler.request_pages(req)}"
                f" pages; hot tier holds {self.pool.capacity}")
        if cost > self.scheduler.cfg.max_active_tokens:
            raise ValueError(f"request {req.rid} exceeds the token budget")
        self.requests[req.rid] = req
        self.scheduler.submit(req, self._tick)
        if self.tracer.enabled:
            # request lifecycle span: submit -> last token (or rejection);
            # async because it crosses many engine scopes
            self.tracer.async_begin(
                "requests", f"req{req.rid}", req.rid, cat="request",
                prompt_tokens=len(req.prompt),
                max_new_tokens=req.max_new_tokens,
                priority=req.priority, ttft_deadline=req.ttft_deadline)

    # ------------------------------------------------------------------ #
    def _live_slots(self) -> List[int]:
        """Slots that decode this tick: occupied, not paused, and not still
        mid-chunked-prefill (a chunking slot has no first token yet)."""
        return [i for i, r in enumerate(self.slot_req)
                if r is not None and not self.paused[i]
                and i not in self._chunk]

    def _active_tokens(self) -> int:
        """Budget charge of the live batch — the SAME cost function the
        scheduler uses at admission (`AdmissionScheduler.request_cost`), so
        per-tick accounting can never drift from submit-time checks."""
        return sum(self.scheduler.request_cost(r)
                   for r in self.slot_req if r is not None)

    def _live_page_count(self) -> int:
        return sum(len(self.slot_pages[i])
                   for i, r in enumerate(self.slot_req) if r is not None)

    # ------------------------------------------------------------------ #
    # admission + per-slot prefill
    # ------------------------------------------------------------------ #
    def _run_admission(self) -> List[Admission]:
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        return self.scheduler.admit(
            free,
            active_tokens=self._active_tokens(),
            free_hot_frames=self.pool.capacity - self._live_page_count(),
            now=self._tick,
            total_hot_frames=self.pool.capacity)

    def _admit(self):
        self._place(self._run_admission())
        if self.scheduler.cfg.policy == "fcfs":
            return
        # preemptive policies: while the policy-ordered head is still queued
        # and a running victim should yield, swap the victim out (pages to
        # the cold tier, request requeued) and retry admission. Bounded by
        # the slot count — at most one preemption per occupied slot per tick.
        for _ in range(len(self.slot_req)):
            cand = self.scheduler.head()
            if cand is None:
                return
            victim = self._preemption_victim(cand)
            if victim is None:
                return
            if self.tracer.enabled:
                policy = self.scheduler.cfg.policy
                self.tracer.decision(
                    "preempt", rid=self.slot_req[victim].rid, slot=victim,
                    for_rid=cand.rid, policy=policy,
                    reason=("deadline-lookahead" if policy == "slo-edf"
                            else "priority"))
            self._preempt_to_queue(victim)
            self._place(self._run_admission())

    def _place(self, admissions: List[Admission]):
        """Route admissions: swapped-out requests resume from saved pages,
        long prompts start chunked prefill, fully-shared prompts skip
        compute, the rest batch into per-bucket prefill groups."""
        if self.tracer.enabled:
            for a in admissions:
                # slot-occupancy span: one per admission episode, keyed by
                # the occupying request (a preempted request re-opens one)
                self.tracer.async_begin(
                    "slots", f"slot{a.slot}", a.request.rid, cat="slot",
                    slot=a.slot, rid=a.request.rid)
        by_bucket: Dict[int, List[Admission]] = {}
        for a in admissions:
            if a.request.rid in self._swapped:
                self._resume_swapped(a)
                continue
            if self._try_shared_prefill(a):
                continue                     # served without prefill compute
            chunk = self.cfg.prefill_chunk_tokens
            if chunk and len(a.request.prompt) > chunk:
                self._start_chunk(a)
                continue
            by_bucket.setdefault(a.bucket, []).append(a)
        for bucket, group in sorted(by_bucket.items()):
            self._prefill_group(bucket, group)

    # ------------------------------------------------------------------ #
    # policy-driven preemption (swap-out to the cold tier + requeue)
    # ------------------------------------------------------------------ #
    def _occupied_slots(self) -> List[int]:
        """Preemption-victim candidates: occupied, not manually paused (a
        paused slot's pages are already cold and its slot is a user
        decision, not the scheduler's to reassign)."""
        return [i for i, r in enumerate(self.slot_req)
                if r is not None and not self.paused[i]]

    def _remaining_ticks(self, slot: int) -> int:
        """Estimated ticks until `slot` frees naturally: one token per tick
        plus, mid-chunked-prefill, the remaining chunk passes."""
        r = self.slot_req[slot]
        rem = r.max_new_tokens - len(r.out_tokens)
        st = self._chunk.get(slot)
        if st is not None:
            chunk = self.cfg.prefill_chunk_tokens
            left = len(st["prompt"]) - st["filled"]
            rem += -(-left // chunk)
        return max(rem, 0)

    def _preemption_victim(self, cand: Request) -> Optional[int]:
        """Pick the slot to swap out for queued request `cand`, or None.

        priority: any running request with strictly lower priority may
        yield — lowest priority first, latest-admitted within a tie (least
        sunk work). slo-edf: preempt ONLY when cand's TTFT deadline would
        otherwise be missed (no slot frees up in time on its own); the
        victim is the running request with the LATEST pending deadline
        (none at all preferred) — never one more urgent than cand.
        """
        occupied = self._occupied_slots()
        if not occupied:
            return None
        policy = self.scheduler.cfg.policy
        if policy == "priority":
            victims = [i for i in occupied
                       if self.slot_req[i].priority < cand.priority]
            if not victims:
                return None
            return min(victims, key=lambda i: (self.slot_req[i].priority,
                                               -self.slot_req[i].admit_tick))
        if policy == "slo-edf":
            deadline = cand.deadline_tick()
            if deadline == float("inf"):
                return None                  # no deadline, no urgency
            if self._tick + min(self._remaining_ticks(i)
                                for i in occupied) <= deadline:
                return None                  # a slot frees up in time
            victims = [i for i in occupied
                       if self.slot_req[i].deadline_tick() > deadline]
            if not victims:
                return None
            return max(victims,
                       key=lambda i: (self.slot_req[i].deadline_tick(),
                                      self.slot_req[i].admit_tick))
        return None

    def _preempt_to_queue(self, slot: int):
        """Swap a running request out of its slot: private pages spill to
        the cold tier (shared prefix pages stay hot for their other
        readers), non-pageable (recurrent) rows and chunked-prefill
        progress are snapshotted, and the request requeues for readmission
        — where it resumes mid-stream, token-for-token."""
        req = self.slot_req[slot]
        state = {
            "pages": self.slot_pages[slot],
            "slot_len": int(self.slot_len[slot]),
            "nonpageable": self._nonpageable_rows(slot),
            "chunk": self._chunk.pop(slot, None),
        }
        self.pool.evict_pages([pid for pid in state["pages"]
                               if self.pool.pages[pid].refcount == 1])
        self._swapped[req.rid] = state
        self.slot_req[slot] = None
        self.slot_pages[slot] = []
        self.slot_len[slot] = 0
        self.paused[slot] = False
        self.metrics.preemptions += 1
        self.scheduler.requeue(req, now=self._tick)
        if self.tracer.enabled:
            self.tracer.async_end("slots", f"slot{slot}", req.rid,
                                  cat="slot", preempted=True)

    def _resume_swapped(self, a: Admission):
        """Readmit a swapped-out request: saved pages re-attach to the new
        slot (still cold — the next decode step's planned preload restores
        them, counted as page faults), non-pageable rows are written back,
        and an interrupted chunked prefill picks up where it left off."""
        state = self._swapped.pop(a.request.rid)
        req = a.request
        req.resuming = False
        slot = a.slot
        self.slot_req[slot] = req
        self.slot_pages[slot] = state["pages"]
        self.slot_len[slot] = state["slot_len"]
        self.paused[slot] = False
        if state["nonpageable"]:
            self._write_nonpageable_rows(slot, state["nonpageable"])
        if state["chunk"] is not None:
            self._chunk[slot] = state["chunk"]
        self.pool.note_deadline(state["pages"], req.deadline_tick())
        self.metrics.readmissions += 1
        if self.tracer.enabled:
            self.tracer.decision("resume", rid=req.rid, slot=slot,
                                 pages=len(state["pages"]))

    def _try_shared_prefill(self, a: Admission) -> bool:
        """Admit a request whose WHOLE prompt is already resident as shared
        pages without running prefill compute (ROADMAP prefix-cache compute
        reuse): every full page of the (bucketed) prompt hits the prefix
        index and the first-token logits were cached by the prefill that
        built those pages. Only page-aligned prompts qualify (a partial tail
        page is private and would still need compute), and only when the
        model carries no recurrent state (which pages cannot rebuild)."""
        P = self.cfg.page_tokens
        prompt = a.request.prompt[-a.bucket:]
        n = len(prompt)
        if (not self.cfg.share_prefix_pages or not self.layout.features
                or self._has_recurrent or n == 0 or n % P):
            return False
        key = (a.bucket, tuple(prompt))
        logits = self._prompt_logits.get(key)
        if logits is None:
            return False
        page_keys = [(a.bucket, tuple(prompt[:(j + 1) * P]))
                     for j in range(n // P)]
        if any(k not in self.pool.prefix_index for k in page_keys):
            return False
        pids = [self.pool.lookup_shared(k) for k in page_keys]
        self.pool.note_deadline(pids, a.request.deadline_tick())
        self.slot_req[a.slot] = a.request
        self.slot_pages[a.slot] = pids
        self.slot_len[a.slot] = n
        self.paused[a.slot] = False
        self.metrics.prefill_skips += 1
        self._prompt_logits.move_to_end(key)
        self._emit_token(a.slot, logits)
        return True

    def _write_prompt_pages(self, slot: int, key_bucket: int,
                            prompt: List[int], lo: int, hi: int,
                            packed, working: set):
        """Allocate (or prefix-share) and fill the pages covering prompt
        tokens [lo, hi) of `slot`, appending to its page table. `packed` is
        this slot's (S >= hi, F) packed KV rows; `lo` must be page-aligned.
        FULL pages are shareable under (key_bucket, prompt-prefix) keys —
        identical whether written monolithically or chunk-by-chunk."""
        P = self.cfg.page_tokens
        req = self.slot_req[slot]
        pids = self.slot_pages[slot]
        assert lo % P == 0 and lo // P == len(pids)
        for j in range(lo // P, -(-hi // P)):
            plo, phi = j * P, min((j + 1) * P, hi)
            if phi == (j + 1) * P:          # full page: shareable
                key = (key_bucket, tuple(prompt[:phi]))
                pid = self.pool.lookup_shared(key)
                if pid is None:
                    pid = self.pool.alloc(shared_key=key
                                          if self.cfg.share_prefix_pages
                                          else None,
                                          needed=working)
                    self.pool.write_page(pid, packed[plo:phi], phi - plo)
            else:                            # partial tail page: private
                pid = self.pool.alloc(needed=working)
                self.pool.write_page(pid, packed[plo:phi], phi - plo)
            pids.append(pid)
            working.add(pid)
        self.pool.note_deadline(pids, req.deadline_tick())

    def _prefill_group(self, bucket: int, group: List[Admission]):
        with self.tracer.span("engine", f"prefill@{bucket}"):
            self._prefill_group_inner(bucket, group)

    def _prefill_group_inner(self, bucket: int, group: List[Admission]):
        B, P = self.cfg.batch_slots, self.cfg.page_tokens
        toks = np.zeros((B, bucket), np.int32)
        lengths = np.ones((B,), np.int32)
        prompts: Dict[int, List[int]] = {}
        for a in group:
            prompt = a.request.prompt[-bucket:]      # right-pad, keep tail
            toks[a.slot, :len(prompt)] = prompt
            lengths[a.slot] = len(prompt)
            prompts[a.slot] = prompt
            self.slot_req[a.slot] = a.request
        batch = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lengths)}
        logits, caches = self._prefill_for(bucket)(self.params, batch)
        self.metrics.prefills += 1
        packed = (self.layout.pack(caches)
                  if self.layout.features else None)   # (B, bucket, F)

        # pages every live slot (and this admission group so far) still
        # needs: allocations must not evict them mid-step
        working = {pid for pages in self.slot_pages for pid in pages}
        for a in group:
            slot, prompt = a.slot, prompts[a.slot]
            n = len(prompt)
            self.slot_pages[slot] = []
            if self.layout.features:
                self._write_prompt_pages(slot, bucket, prompt, 0, n,
                                         packed[slot], working)
            self.slot_len[slot] = n
            self.paused[slot] = False
            self._merge_resident(caches, slot)
            if (self.cfg.share_prefix_pages and self.layout.features
                    and not self._has_recurrent and n and n % P == 0):
                # whole prompt landed in shared pages: cache the first-token
                # logits so an identical prompt can skip prefill entirely
                self._prompt_logits[(bucket, tuple(prompt))] = \
                    np.asarray(logits[slot])
                if len(self._prompt_logits) > 512:
                    self._prompt_logits.popitem(last=False)
            self._emit_token(slot, np.asarray(logits[slot]))

    # ------------------------------------------------------------------ #
    # chunked prefill: one bounded pass per tick, interleaved with decode
    # ------------------------------------------------------------------ #
    def _start_chunk(self, a: Admission):
        """Claim the slot for a long prompt without running any prefill
        yet; `_advance_chunks` fills it one page-aligned chunk per tick.
        The slot stays out of the decode batch until the prompt completes."""
        self.slot_req[a.slot] = a.request
        self.slot_pages[a.slot] = []
        self.slot_len[a.slot] = 0
        self.paused[a.slot] = False
        self._chunk[a.slot] = {"prompt": a.request.prompt[-a.bucket:],
                               "filled": 0}

    def _advance_chunks(self):
        for slot in sorted(self._chunk):
            with self.tracer.span("engine", f"chunk-pass@{slot}"):
                self._chunk_pass(slot)

    def _chunk_pass(self, slot: int):
        """One chunked-prefill pass: extend the slot's prefix by (up to)
        `prefill_chunk_tokens` tokens and bank the new pages. The pass runs
        the compiled prefill of the SMALLEST bucket holding the prefix so
        far — per-tick prefill span is bounded by the prefix, and causal
        attention makes the rows bitwise identical to a monolithic prefill
        (row t depends only on tokens <= t; padding rows are masked to
        exact zeros). The final pass — the same shape the dense reference
        uses — merges non-pageable (recurrent) state and emits the first
        token, so chunking is invisible in the token stream."""
        st = self._chunk[slot]
        req = self.slot_req[slot]
        prompt, f = st["prompt"], st["filled"]
        n = len(prompt)
        hi = min(f + self.cfg.prefill_chunk_tokens, n)
        bucket = self.scheduler.pick_bucket(hi)
        B, P = self.cfg.batch_slots, self.cfg.page_tokens
        toks = np.zeros((B, bucket), np.int32)
        toks[slot, :hi] = prompt[:hi]
        lengths = np.ones((B,), np.int32)
        lengths[slot] = hi
        logits, caches = self._prefill_for(bucket)(
            self.params, {"tokens": jnp.asarray(toks),
                          "lengths": jnp.asarray(lengths)})
        self.metrics.prefills += 1
        self.metrics.chunk_passes += 1
        working = {pid for pages in self.slot_pages for pid in pages}
        if self.layout.features:
            packed = self.layout.pack(caches)
            self._write_prompt_pages(slot, req.bucket, prompt, f, hi,
                                     packed[slot], working)
        st["filled"] = hi
        self.slot_len[slot] = hi
        if hi < n:
            return                          # more chunks to go; decode runs on
        del self._chunk[slot]               # prompt complete: slot goes live
        self._merge_resident(caches, slot)
        if (self.cfg.share_prefix_pages and self.layout.features
                and not self._has_recurrent and n and n % P == 0):
            self._prompt_logits[(req.bucket, tuple(prompt))] = \
                np.asarray(logits[slot])
            if len(self._prompt_logits) > 512:
                self._prompt_logits.popitem(last=False)
        self._emit_token(slot, np.asarray(logits[slot]))

    def _merge_resident(self, fresh, slot: int):
        """Copy one slot's NON-pageable cache rows (SSM states, idx) from a
        freshly prefilled tree into the carried resident tree."""
        pageable = {e.keys for e in self.layout.entries}
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.resident)
        flat_fresh = dict(jax.tree_util.tree_flatten_with_path(fresh)[0])
        out = []
        for path, leaf in flat:
            keys = _path_keys(path)
            if keys in pageable:
                out.append(leaf)
                continue
            src = flat_fresh[path]
            ax = 1 if keys[0] == "groups" else 0
            idx = (slice(None),) * ax + (slot,)
            out.append(leaf.at[idx].set(src[idx].astype(leaf.dtype)))
        self.resident = jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------ #
    # decode
    # ------------------------------------------------------------------ #
    def _set_idx(self, tree, idx: np.ndarray):
        """Overwrite every cache `idx` leaf with per-slot fill levels."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        vec = jnp.asarray(idx, jnp.int32)
        out = []
        for path, leaf in flat:
            keys = _path_keys(path)
            if keys[-1] == "idx":
                leaf = jnp.broadcast_to(vec, leaf.shape).astype(leaf.dtype)
            out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out)

    def _assemble(self) -> Any:
        """Build the decode cache tree: pages -> dense token-indexed view."""
        if not self.layout.features:
            return self._set_idx(self.resident, self.slot_len)
        B, P = self.cfg.batch_slots, self.cfg.page_tokens
        frames = np.full((B, self.n_pages_per_slot), ZERO_FRAME, np.int32)
        for i in self._live_slots():
            pids = self.slot_pages[i]
            frames[i, :len(pids)] = self.pool.frames_of(pids)
        store = self.pool.packed_store()
        if self.cfg.use_pallas_gather:
            from repro.kernels import pul_page_gather
            from repro.core import PULConfig
            d = min(self.pool.distance, self.pool.cfg.fifo_depth)
            packed = pul_page_gather(
                store, jnp.asarray(frames),
                cfg=PULConfig(distance=max(1, d)))
        else:
            packed = store[jnp.asarray(frames)].reshape(
                B, self.cfg.max_seq, -1)
        tree = self.layout.unpack_into(self.resident, packed)
        return self._set_idx(tree, self.slot_len)

    def _ensure_tail_pages(self):
        """Every live slot needs a writable page for the incoming token.
        The step's whole working set is threaded into alloc so a tail-page
        allocation can never evict a page this very step still reads (which
        ensure_hot would immediately restore — churn, not capacity)."""
        P = self.cfg.page_tokens
        live = self._live_slots()
        working = {pid for i in live for pid in self.slot_pages[i]}
        for i in live:
            pos = int(self.slot_len[i])
            if pos // P == len(self.slot_pages[i]):
                pid = self.pool.alloc(needed=working)
                self.pool.note_deadline([pid],
                                        self.slot_req[i].deadline_tick())
                self.slot_pages[i].append(pid)
                working.add(pid)

    def _sweep_cache_tree(self):
        """Decode cache tree for the single-sweep path: pageable leaves are
        tiny placeholders — the sweep branch reads only the tree POSITION
        (the KV data rides in the donated planes), so no page view is ever
        materialized into the tree. Grouped placeholders keep a leading
        layer axis so the backbone scan can slice them; non-pageable leaves
        (SSM state, idx) come from `resident` as usual."""
        pageable = {e.keys: e for e in self.layout.entries}

        def repl(path, leaf):
            e = pageable.get(_path_keys(path))
            if e is None:
                return leaf
            if e.grouped:
                return jnp.zeros((e.shape[0], 1), leaf.dtype)
            return jnp.zeros((1,), leaf.dtype)

        return jax.tree_util.tree_map_with_path(repl, self.resident)

    def _paged_kernel_decode(self, live, toks, pos0, frames, offs):
        """Kernel-true decode: attention streams straight over page frames;
        no dense per-slot KV view is assembled.

        ``sweep_decode=True`` (default) runs ONE sweep: the layer scan
        carries the full per-layer planes (``layer_view`` is zero-copy —
        the plane IS the stored array), the kernel picks its layer via an
        SMEM scalar, and its fused epilogue commits the current token's
        rows into each slot's tail page inside the same launch. The planes
        are donated to the jit call, so the hot tier updates in place.
        ``sweep_decode=False`` keeps per-layer launches over per-layer
        views, with the caller doing the eager write_rows scatter (the
        parity baseline).

        Returns (logits, new_tree); new_tree's pageable leaves hold only
        the current token's rows."""
        B = self.cfg.batch_slots
        page_table = np.full((B, self.n_pages_per_slot), ZERO_FRAME, np.int32)
        for i in live:
            pids = self.slot_pages[i]
            page_table[i, :len(pids)] = self.pool.frames_of(pids)
        if self.cfg.sweep_decode:
            tree = self._set_idx(self._sweep_cache_tree(), self.slot_len)
            # account + lifecycle-trace the fused commit BEFORE the launch
            # (events must precede the write they describe)
            self.pool.note_fused_commit(frames, offs)
            logits, new_tree, planes = self._sweep_decode(
                self.params, {"tokens": jnp.asarray(toks),
                              "pos0": jnp.asarray(pos0),
                              "page_table": jnp.asarray(page_table),
                              "frames": jnp.asarray(frames),
                              "offsets": jnp.asarray(offs)},
                tree, self.pool.planes)
            self.pool.planes = planes
            return logits, new_tree
        tree = self.layout.page_view_tree(self.resident, self.pool.planes)
        tree = self._set_idx(tree, self.slot_len)
        return self._paged_decode(
            self.params, {"tokens": jnp.asarray(toks),
                          "pos0": jnp.asarray(pos0),
                          "page_table": jnp.asarray(page_table)}, tree)

    def _merge_nonpageable(self, new_tree):
        """Fold a paged-decode step's NON-pageable outputs (SSM state, idx)
        into the resident tree; pageable leaves (page views in, new-token
        rows out) never live in `resident`."""
        pageable = {e.keys for e in self.layout.entries}
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.resident)
        flat_new = dict(jax.tree_util.tree_flatten_with_path(new_tree)[0])
        out = []
        for path, leaf in flat:
            keys = _path_keys(path)
            out.append(leaf if keys in pageable else flat_new[path])
        self.resident = jax.tree_util.tree_unflatten(treedef, out)

    def _decode_step(self):
        live = self._live_slots()
        if not live:
            return
        B = self.cfg.batch_slots
        self._ensure_tail_pages()
        needed = sorted({pid for i in live for pid in self.slot_pages[i]})
        faults = self.pool.ensure_hot(needed)

        toks = np.zeros((B, 1), np.int32)
        pos0 = np.zeros((B,), np.int32)
        for i in live:
            toks[i, 0] = self.slot_req[i].out_tokens[-1]
            pos0[i] = self.slot_len[i]
        # tail-page commit coordinates for every slot this step (TRASH sink
        # for slots not decoding); the fused sweep needs them BEFORE launch
        P = self.cfg.page_tokens
        frames = np.full((B,), TRASH_FRAME, np.int32)
        offs = np.zeros((B,), np.int32)
        if self.layout.features:
            for i in live:
                pos = int(self.slot_len[i])
                pid = self.slot_pages[i][pos // P]
                frames[i] = self.pool.pages[pid].frame
                offs[i] = pos % P
        kernel_true = self.cfg.use_paged_kernel and self.layout.features
        sweep = kernel_true and self.cfg.sweep_decode
        if kernel_true:
            logits, new_tree = self._paged_kernel_decode(
                live, toks, pos0, frames, offs)
        else:
            tree = self._assemble()
            logits, new_tree = self._decode(
                self.params, {"tokens": jnp.asarray(toks),
                              "pos0": jnp.asarray(pos0)}, tree)
        self.metrics.decode_steps += 1

        # write the step's new KV rows back into each live slot's tail page
        # (the sweep already committed them in its fused epilogue)
        if self.layout.features and not sweep:
            rows = (self.layout._pack_new_rows_impl(new_tree) if kernel_true
                    else self.layout.pack_rows(new_tree,
                                               jnp.asarray(self.slot_len)))
            self.pool.write_rows(frames, offs, rows)
        if kernel_true:
            self._merge_nonpageable(new_tree)
        else:
            self.resident = new_tree

        logits = np.asarray(logits)
        for i in live:
            self.slot_len[i] += 1
            self._emit_token(i, logits[i])
        return faults

    def _emit_token(self, slot: int, logits: np.ndarray):
        r = self.slot_req[slot]
        if self.cfg.greedy:
            nxt = int(np.argmax(logits))
        else:
            z = logits.astype(np.float64) - logits.max()
            p = np.exp(z)
            nxt = int(self._rng.choice(p.shape[-1], p=p / p.sum()))
        r.out_tokens.append(nxt)
        self.metrics.tokens_emitted += 1
        if r.first_token_tick < 0:
            r.first_token_tick = self._tick
            if r.ttft_deadline >= 0 and r.ttft > r.ttft_deadline:
                self.metrics.slo_violations += 1
        out_of_room = int(self.slot_len[slot]) + 1 >= self.cfg.max_seq
        if len(r.out_tokens) >= r.max_new_tokens or out_of_room:
            self._finish(slot)

    def _finish(self, slot: int):
        req = self.slot_req[slot]
        req.done = True
        if self.tracer.enabled:
            self.tracer.async_end("requests", f"req{req.rid}", req.rid,
                                  cat="request", tokens=len(req.out_tokens))
            self.tracer.async_end("slots", f"slot{slot}", req.rid,
                                  cat="slot")
        for pid in self.slot_pages[slot]:
            self.pool.unref(pid)
        self.slot_pages[slot] = []
        self.slot_req[slot] = None
        self.slot_len[slot] = 0
        self.paused[slot] = False
        self._paused_state.pop(slot, None)

    # ------------------------------------------------------------------ #
    # preemption (vLLM-style swap-out: pages spill to the cold tier)
    # ------------------------------------------------------------------ #
    def _nonpageable_rows(self, slot: int) -> Dict[Tuple[str, ...], Any]:
        """Snapshot one slot's rows of every NON-pageable cache leaf (SSM /
        recurrent state). Attention KV needs no snapshot — it is rebuilt
        from pages — but recurrent state advances in `resident` every decode
        step, including for paused slots fed dummy tokens."""
        pageable = {e.keys for e in self.layout.entries}
        out = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.resident)[0]:
            keys = _path_keys(path)
            if keys in pageable or keys[-1] == "idx":
                continue
            ax = 1 if keys[0] == "groups" else 0
            out[keys] = leaf[(slice(None),) * ax + (slot,)]
        return out

    def _write_nonpageable_rows(self, slot: int,
                                saved: Dict[Tuple[str, ...], Any]):
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.resident)
        out = []
        for path, leaf in flat:
            keys = _path_keys(path)
            if keys in saved:
                ax = 1 if keys[0] == "groups" else 0
                idx = (slice(None),) * ax + (slot,)
                leaf = leaf.at[idx].set(saved[keys])
            out.append(leaf)
        self.resident = jax.tree_util.tree_unflatten(treedef, out)

    def preempt(self, slot: int):
        """Pause a slot and evict its private pages to the cold tier.
        Shared prefix pages stay hot while other requests reference them.
        Recurrent (non-pageable) state is snapshotted: paused slots still
        ride through the batched decode step with dummy inputs, which would
        otherwise advance their SSM/conv state."""
        assert self.slot_req[slot] is not None
        if self.tracer.enabled:
            self.tracer.instant("engine", "pause", slot=slot,
                                rid=self.slot_req[slot].rid)
        self.paused[slot] = True
        self._paused_state[slot] = self._nonpageable_rows(slot)
        self.pool.evict_pages(
            [pid for pid in self.slot_pages[slot]
             if self.pool.pages[pid].refcount == 1])

    def resume(self, slot: int):
        """Un-pause; the next decode step's ensure_hot restores the pages
        through the planned preload path (counted as page faults), and the
        snapshotted recurrent state is written back."""
        assert self.slot_req[slot] is not None
        if self.tracer.enabled:
            self.tracer.instant("engine", "unpause", slot=slot,
                                rid=self.slot_req[slot].rid)
        self.paused[slot] = False
        saved = self._paused_state.pop(slot, None)
        if saved:
            self._write_nonpageable_rows(slot, saved)

    # ------------------------------------------------------------------ #
    def step(self):
        t0 = time.perf_counter()
        tr = self.tracer
        tr.set_tick(self._tick)
        with tr.span("engine", "tick"):
            with tr.span("engine", "admit"):
                self._admit()
            self._advance_chunks()
            with tr.span("engine", "decode"):
                faults = self._decode_step() or 0
        self._tick += 1
        self.metrics.ticks = self._tick
        self.metrics.wall_time += time.perf_counter() - t0
        if tr.enabled:
            tr.counter("gauges", "live_slots", len(self._live_slots()))
            tr.counter("gauges", "queued", len(self.scheduler))
            tr.counter("gauges", "hot_pages_in_use", self.pool.hot_in_use())
            tr.counter("gauges", "page_faults_step", faults)
        if self._shadow_checker is not None:
            self._run_shadow_check()
        if self.metrics_hook:
            # snapshot() runs OUTSIDE the guard: a PoolMetrics invariant
            # violation must still crash loudly. Only the user-supplied
            # observer is sandboxed — a broken hook must not take the tick
            # loop down with it, so it is disabled after its first raise.
            snap = self.snapshot(page_faults_step=faults)
            try:
                self.metrics_hook(snap)
            except Exception as e:
                warnings.warn(
                    f"metrics_hook raised {e!r}; disabling the hook for the "
                    "rest of this engine's life", RuntimeWarning,
                    stacklevel=2)
                self.metrics_hook = None

    def _run_shadow_check(self):
        """Feed the tick's new trace events through the lifecycle checker;
        raise at the first violation (with event provenance)."""
        from repro.analysis.sanitizer import LifecycleViolationError
        fresh = self._shadow_checker.feed_log(self.pool.trace)
        if fresh:
            raise LifecycleViolationError(fresh)

    def snapshot(self, **extra) -> Dict[str, Any]:
        pm = self.pool.metrics
        pm.validate()   # counter-arithmetic invariants (PoolMetrics docs)
        lat = self.scheduler.queue_latencies()
        snap = {
            "tick": self._tick,
            "policy": self.scheduler.cfg.policy,
            "tokens_emitted": self.metrics.tokens_emitted,
            "tokens_per_sec": self.metrics.tokens_per_sec,
            "prefills": self.metrics.prefills,
            "prefill_skips": self.metrics.prefill_skips,
            "chunk_passes": self.metrics.chunk_passes,
            "preemptions": self.metrics.preemptions,
            "readmissions": self.metrics.readmissions,
            "slo_violations": self.metrics.slo_violations,
            "rejected": self.scheduler.rejected,
            "swapped_out": len(self._swapped),
            "live_slots": len(self._live_slots()),
            "queued": len(self.scheduler),
            "page_faults": pm.page_faults,
            "evictions": pm.evictions,
            "shared_page_hits": pm.shared_hits,
            "pages_allocated": pm.pages_allocated,
            "hot_pages_in_use": self.pool.hot_in_use(),
            "preload_distance": self.pool.distance,
            "modeled_restore_latency_hidden": pm.modeled_latency_hidden,
            "mean_queue_latency": mean(lat),
        }
        snap.update(extra)
        return snap

    def economics(self) -> Dict[str, Any]:
        """Cache economics of the run so far: bytes moved per token emitted
        per tier, and prefetch accuracy / timeliness / coverage of the
        planned d* restores (see ``repro.obs.metrics.cache_economics``)."""
        return cache_economics(page_bytes=self.pool.page_bytes,
                               tokens_emitted=self.metrics.tokens_emitted,
                               pool_metrics=self.pool.metrics)

    def metrics_registry(self) -> MetricsRegistry:
        """Current counters as a flat registry (JSON / Prometheus export)."""
        reg = MetricsRegistry()
        policy = self.scheduler.cfg.policy
        for k, v in self.snapshot().items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            reg.set(f"pul_engine_{k}", v, policy=policy)
        economics_into_registry(reg, self.economics(), policy=policy)
        return reg

    def run(self, max_ticks: int = 1000) -> Dict[int, List[int]]:
        """Drive steps until every submitted request completes (or the tick
        cap); returns {rid: generated tokens} for ALL submitted requests."""
        pending = lambda: (len(self.scheduler)
                           or any(r is not None for r in self.slot_req))
        ticks = 0
        while pending() and ticks < max_ticks:
            self.step()
            ticks += 1
        return _drain_results(self.requests)

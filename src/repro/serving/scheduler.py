"""Admission control for the paged serving engine.

Token-budget continuous batching: requests queue FIFO; a request is admitted
into a free slot when (a) a slot is free, (b) the batch's token budget —
the sum over live slots of worst-case final length (prefill bucket +
max_new_tokens) — stays within ``max_active_tokens``, and (c) the paged KV
pool has hot frames for its worst-case page count. Admission picks the
smallest prefill bucket that fits the prompt (prefix-length bucketing: one
compiled prefill per bucket serves all lengths in it, and same-bucket
requests sharing a page-aligned prompt prefix share prompt pages bitwise).

Queue latency (submit tick -> admit tick) is recorded per request and
surfaced through the engine's metrics hook.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Request:
    """One generation request (also used by the dense reference engine)."""

    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # paged-engine bookkeeping
    submit_tick: int = -1
    admit_tick: int = -1
    bucket: int = 0

    @property
    def queue_latency(self) -> int:
        """Engine ticks spent queued before admission (-1: never admitted)."""
        if self.admit_tick < 0:
            return -1
        return self.admit_tick - self.submit_tick


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    prefill_buckets: Tuple[int, ...] = (16, 32, 64)
    max_active_tokens: int = 0          # 0 -> unlimited (slots are the cap)
    page_tokens: int = 16

    def __post_init__(self):
        if not self.prefill_buckets:
            raise ValueError("need at least one prefill bucket")
        if tuple(sorted(self.prefill_buckets)) != tuple(self.prefill_buckets):
            raise ValueError("prefill_buckets must be ascending")


@dataclasses.dataclass(frozen=True)
class Admission:
    slot: int
    request: Request
    bucket: int


class AdmissionScheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.queue: List[Request] = []
        # latency VALUES, not Request objects: admitted requests must not be
        # retained here forever (prompt/out_tokens would leak in a
        # long-lived engine)
        self._latencies: List[int] = []

    def __len__(self) -> int:
        return len(self.queue)

    # ------------------------------------------------------------------ #
    def submit(self, req: Request, now: int):
        req.submit_tick = now
        self.queue.append(req)

    def pick_bucket(self, prompt_len: int) -> int:
        for b in self.cfg.prefill_buckets:
            if prompt_len <= b:
                return b
        return self.cfg.prefill_buckets[-1]

    def request_cost(self, req: Request) -> int:
        """Worst-case final token count (budget unit).

        THE cost function of the token budget: submit-time rejection,
        admission, and the engine's per-tick accounting
        (`PagedServingEngine._active_tokens`) all charge this — one
        definition, so the budget can never drift between checks."""
        bucket = self.pick_bucket(len(req.prompt))
        return min(len(req.prompt), bucket) + req.max_new_tokens

    def request_pages(self, req: Request) -> int:
        P = self.cfg.page_tokens
        return -(-self.request_cost(req) // P)

    # ------------------------------------------------------------------ #
    def admit(
        self,
        free_slots: Sequence[int],
        *,
        active_tokens: int,
        free_hot_frames: int,
        now: int,
    ) -> List[Admission]:
        """FIFO admission under slot / token / page budgets.

        Strict FCFS: the head of the queue blocks later requests (no
        reordering), keeping queue-latency semantics predictable.
        """
        out: List[Admission] = []
        free = list(free_slots)
        budget = self.cfg.max_active_tokens
        tokens = active_tokens
        frames = free_hot_frames
        while self.queue and free:
            req = self.queue[0]
            cost = self.request_cost(req)
            pages = self.request_pages(req)
            if budget and tokens + cost > budget:
                break
            if pages > frames:
                break
            self.queue.pop(0)
            req.admit_tick = now
            req.bucket = self.pick_bucket(len(req.prompt))
            tokens += cost
            frames -= pages
            slot = free.pop(0)
            out.append(Admission(slot=slot, request=req, bucket=req.bucket))
            self._latencies.append(req.queue_latency)
        return out

    # ------------------------------------------------------------------ #
    def queue_latencies(self) -> List[int]:
        return [l for l in self._latencies if l >= 0]

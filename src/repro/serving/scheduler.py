"""Admission control for the paged serving engine.

Token-budget continuous batching with pluggable scheduling policies:

  * ``fcfs``     — strict FIFO (the original behavior): the head of the
    queue blocks later requests, keeping queue-latency semantics
    predictable. Never preempts.
  * ``priority`` — higher ``Request.priority`` admits first; the engine
    preempts lower-priority *running* requests (vLLM-style swap-out to the
    cold tier) when a higher-priority arrival cannot be admitted.
  * ``slo-edf``  — earliest-deadline-first on per-request TTFT deadlines
    (``Request.ttft_deadline``, in engine ticks from submit). Requests that
    already emitted their first token have no pending deadline and sort
    last; the engine preempts only when a pending deadline would otherwise
    be missed.

A request is admitted into a free slot when (a) a slot is free, (b) the
batch's token budget — the sum over live slots of worst-case final length
(full prompt + max_new_tokens) — stays within ``max_active_tokens``, and
(c) the paged KV pool has hot frames for its worst-case page count.
Admission picks the smallest prefill bucket that fits the prompt
(prefix-length bucketing: one compiled prefill per bucket serves all
lengths in it). Prompts longer than the largest configured bucket use
``max_seq`` as an implicit top bucket — they are never silently truncated;
prompts that cannot fit a slot at all are rejected, not queued.

Queue latency (submit tick -> admit tick) is recorded per request at FIRST
admission (a preempted request's readmission wait is tracked separately via
``Request.preemptions``) and surfaced through the engine's metrics hook.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.obs.tracer import NULL_TRACER

POLICIES = ("fcfs", "priority", "slo-edf")


@dataclasses.dataclass
class Request:
    """One generation request (also used by the dense reference engine)."""

    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    priority: int = 0               # higher = more important (priority policy)
    ttft_deadline: int = -1         # ticks from submit to first token
                                    # (-1: no SLO; slo-edf policy)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    failed: bool = False            # never-admittable: rejected, not served
    error: str = ""
    # paged-engine bookkeeping
    submit_tick: int = -1
    admit_tick: int = -1
    first_token_tick: int = -1
    bucket: int = 0
    preemptions: int = 0            # times swapped out mid-flight
    resuming: bool = False          # requeued after preemption (pages saved)
    _seq: int = -1                  # scheduler arrival order (stable ties)

    @property
    def queue_latency(self) -> int:
        """Engine ticks spent queued before admission (-1: never admitted)."""
        if self.admit_tick < 0:
            return -1
        return self.admit_tick - self.submit_tick

    @property
    def ttft(self) -> int:
        """Ticks from submit to first emitted token (-1: none yet)."""
        if self.first_token_tick < 0:
            return -1
        return self.first_token_tick - self.submit_tick

    def deadline_tick(self) -> float:
        """Absolute tick by which the first token must be emitted (inf:
        no deadline, or the first token is already out — a TTFT deadline
        stops mattering the moment TTFT is fixed)."""
        if self.ttft_deadline < 0 or self.first_token_tick >= 0:
            return math.inf
        return self.submit_tick + self.ttft_deadline


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    prefill_buckets: Tuple[int, ...] = (16, 32, 64)
    max_active_tokens: int = 0          # 0 -> unlimited (slots are the cap)
    page_tokens: int = 16
    policy: str = "fcfs"
    max_seq: int = 0                    # implicit top bucket for prompts
                                        # longer than the largest configured
                                        # bucket (0 -> largest bucket is the
                                        # hard cap)

    def __post_init__(self):
        if not self.prefill_buckets:
            raise ValueError("need at least one prefill bucket")
        if tuple(sorted(self.prefill_buckets)) != tuple(self.prefill_buckets):
            raise ValueError("prefill_buckets must be ascending")
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")


@dataclasses.dataclass(frozen=True)
class Admission:
    slot: int
    request: Request
    bucket: int


class AdmissionScheduler:
    def __init__(self, cfg: SchedulerConfig, tracer=None):
        self.cfg = cfg
        # repro.obs tracer: every admission outcome is an instant on the
        # "sched" track with its machine-readable reason — what
        # tools/trace_diff.py aligns two runs on
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.queue: List[Request] = []
        self.failed: List[Request] = []     # never-admittable rejections
        self.rejected = 0
        # latency VALUES, not Request objects: admitted requests must not be
        # retained here forever (prompt/out_tokens would leak in a
        # long-lived engine)
        self._latencies: List[int] = []
        self._arrivals = 0

    def __len__(self) -> int:
        return len(self.queue)

    # ------------------------------------------------------------------ #
    def submit(self, req: Request, now: int):
        req.submit_tick = now
        req._seq = self._arrivals
        self._arrivals += 1
        self.queue.append(req)

    def requeue(self, req: Request, now: int):
        """Return a preempted (swapped-out) request to the queue. It keeps
        its original submit tick and arrival order, so among equal policy
        keys it readmits before later arrivals."""
        req.resuming = True
        req.preemptions += 1
        self.queue.append(req)
        self._sort()

    def pick_bucket(self, prompt_len: int) -> int:
        for b in self.cfg.prefill_buckets:
            if prompt_len <= b:
                return b
        top = self.cfg.prefill_buckets[-1]
        if self.cfg.max_seq > top:
            return self.cfg.max_seq     # implicit top bucket: never truncate
        return top

    def request_cost(self, req: Request) -> int:
        """Worst-case final token count (budget unit).

        THE cost function of the token budget: submit-time rejection,
        admission, and the engine's per-tick accounting
        (`PagedServingEngine._active_tokens`) all charge this — one
        definition, so the budget can never drift between checks. Charges
        the TRUE prompt length: a prompt longer than the largest prefill
        bucket is served through the implicit ``max_seq`` bucket, never
        silently truncated, so under-charging it would let admission
        oversubscribe both the token budget and the page pool."""
        return len(req.prompt) + req.max_new_tokens

    def request_pages(self, req: Request) -> int:
        P = self.cfg.page_tokens
        return -(-self.request_cost(req) // P)

    # ------------------------------------------------------------------ #
    def _order_key(self, req: Request):
        if self.cfg.policy == "priority":
            return (-req.priority, req._seq)
        if self.cfg.policy == "slo-edf":
            return (req.deadline_tick(), req._seq)
        return (req._seq,)

    def _sort(self):
        # fcfs keys on arrival order, so this is a no-op there except after
        # a requeue, where it reinserts the preempted request at its
        # original position instead of the back
        self.queue.sort(key=self._order_key)

    def head(self) -> Optional[Request]:
        """Most-urgent queued request under the configured policy."""
        self._sort()
        return self.queue[0] if self.queue else None

    def _fail(self, req: Request, reason: str):
        req.failed = True
        req.done = True
        req.error = reason
        self.failed.append(req)
        self.rejected += 1
        if self.tracer.enabled:
            self.tracer.decision("reject", rid=req.rid, reason=reason)
            # close the request's lifecycle span (opened at engine submit)
            self.tracer.async_end("requests", f"req{req.rid}", req.rid,
                                  cat="request", failed=True)

    # ------------------------------------------------------------------ #
    def admit(
        self,
        free_slots: Sequence[int],
        *,
        active_tokens: int,
        free_hot_frames: int,
        now: int,
        total_hot_frames: Optional[int] = None,
    ) -> List[Admission]:
        """Policy-ordered admission under slot / token / page budgets.

        Head-blocking within the policy order: the most-urgent queued
        request blocks later ones (no reordering past it), keeping latency
        semantics predictable — preemptive policies make room by evicting
        running requests (engine side), not by skipping the head.

        A head request that can NEVER be admitted — its page demand exceeds
        the pool's TOTAL hot frames, or its cost exceeds the whole token
        budget — is failed visibly (``Request.failed``, ``self.failed``,
        the ``rejected`` counter) instead of blocking the queue forever:
        waiting cannot make an impossible demand feasible, and a silent
        head-of-queue wedge starves every request behind it.
        """
        out: List[Admission] = []
        free = list(free_slots)
        budget = self.cfg.max_active_tokens
        tokens = active_tokens
        frames = free_hot_frames
        self._sort()
        while self.queue and free:
            req = self.queue[0]
            cost = self.request_cost(req)
            pages = self.request_pages(req)
            if total_hot_frames is not None and pages > total_hot_frames:
                self.queue.pop(0)
                self._fail(req, f"needs {pages} pages; pool holds only "
                                f"{total_hot_frames} hot frames in total")
                continue
            if budget and cost > budget:
                self.queue.pop(0)
                self._fail(req, f"costs {cost} tokens; the whole budget is "
                                f"{budget}")
                continue
            if budget and tokens + cost > budget:
                if self.tracer.enabled:
                    self.tracer.decision(
                        "admission-blocked", rid=req.rid,
                        reason="token-budget", cost=cost,
                        active_tokens=tokens, budget=budget)
                break
            if pages > frames:
                if self.tracer.enabled:
                    self.tracer.decision(
                        "admission-blocked", rid=req.rid,
                        reason="no-hot-frames", pages=pages,
                        free_frames=frames)
                break
            self.queue.pop(0)
            req.admit_tick = now
            req.bucket = self.pick_bucket(len(req.prompt))
            tokens += cost
            frames -= pages
            slot = free.pop(0)
            out.append(Admission(slot=slot, request=req, bucket=req.bucket))
            if self.tracer.enabled:
                self.tracer.decision(
                    "admit", rid=req.rid, slot=slot, bucket=req.bucket,
                    policy=self.cfg.policy, resuming=req.resuming)
            if not req.resuming:
                # queue latency is anchored at FIRST admission; readmission
                # waits are visible via Request.preemptions instead
                self._latencies.append(req.queue_latency)
        return out

    # ------------------------------------------------------------------ #
    def queue_latencies(self) -> List[int]:
        return [l for l in self._latencies if l >= 0]

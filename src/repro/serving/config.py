"""One serving configuration to rule the four layers.

The serving stack historically grew a config dataclass per layer:

  * :class:`~repro.serving.engine.EngineConfig` — dense reference engine;
  * :class:`~repro.serving.engine.PagedEngineConfig` — paged engine;
  * :class:`~repro.serving.scheduler.SchedulerConfig` — admission control;
  * :class:`~repro.serving.kv_pages.PageConfig` — the page pool.

Every entry point had to rebuild the same knobs into whichever subset its
layer wanted, and the launchers each carried their own flag-to-dataclass
plumbing. :class:`ServingConfig` collapses that: ONE documented facade
holding the union of the knobs, with projections onto each layer config
(:meth:`dense`, :meth:`paged`, :meth:`scheduler`, :meth:`pages`) and a
single argparse adapter (:meth:`add_flags` / :meth:`from_flags`) shared by
``repro.launch.serve`` and ``examples/serve_lm.py``.

Both engines accept a ``ServingConfig`` directly — ``ServingEngine``
projects it with :meth:`dense`, ``PagedServingEngine`` with :meth:`paged`
— so callers no longer need to know which layer config a knob lives in.
The per-layer dataclasses remain the internal representation (and remain
accepted), so existing code keeps working unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Default prefill bucket ladder; buckets above max_seq are dropped by
# from_flags/paged (the scheduler requires every bucket <= max_seq).
_BUCKET_LADDER = (16, 32, 64, 128)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Union of every serving knob, documented once.

    Capacity / shape:
      batch_slots: concurrent decode slots (the continuous batch width).
      max_seq: per-slot token capacity (prompt + generated); paged mode
        requires it to be a multiple of ``page_tokens``.

    Sampling:
      greedy: argmax decoding (True) or temperature-1 sampling (False).
      sample_seed: rng seed for ``greedy=False`` — shared by both engines
        so sampled runs stay differential-testable.

    Dense reference engine only:
      prefill_bucket: the single left-padded prefill width.

    Page store (paged engine):
      page_tokens: tokens per KV page.
      hot_pages: fast-tier frames (0 = size for every live slot resident).
      preload_distance: PUL preload distance for page restores
        (None = the planner's d*).
      share_prefix_pages: share page-aligned prompt prefixes across
        requests (and reuse their cached first-token logits).

    Decode path (paged engine):
      use_pallas_gather: route dense assembly through the PUL page gather.
      use_paged_kernel: kernel-true decode straight over page frames.
      sweep_decode: with the kernel, run ALL layers as one sweep over the
        per-layer planes with the token commit fused into the kernel
        epilogue (False = per-layer launches + eager scatter).

    Admission (paged engine):
      prefill_buckets: ascending compiled prefill widths.
      max_active_tokens: token budget across live slots (0 = slots cap).
      policy: "fcfs" | "priority" | "slo-edf" (the latter two preempt).
      prefill_chunk_tokens: page-aligned chunked prefill threshold
        (0 = monolithic prefill at admission).

    Debug:
      shadow_check: trace the page lifecycle and replay it through the
        sanitizer every tick (test-only; zero overhead when off).
    """

    batch_slots: int = 4
    max_seq: int = 256
    greedy: bool = True
    sample_seed: int = 0
    prefill_bucket: int = 64
    page_tokens: int = 16
    hot_pages: int = 0
    preload_distance: Optional[int] = None
    share_prefix_pages: bool = True
    use_pallas_gather: bool = False
    use_paged_kernel: bool = False
    sweep_decode: bool = True
    prefill_buckets: Tuple[int, ...] = (16, 32, 64)
    max_active_tokens: int = 0
    policy: str = "fcfs"
    prefill_chunk_tokens: int = 0
    shadow_check: bool = False

    # ------------------------------------------------------------------ #
    # projections onto the per-layer configs
    # ------------------------------------------------------------------ #
    def dense(self):
        """Project onto the dense reference engine's EngineConfig."""
        from repro.serving.engine import EngineConfig
        return EngineConfig(
            batch_slots=self.batch_slots, max_seq=self.max_seq,
            prefill_bucket=self.prefill_bucket, greedy=self.greedy,
            sample_seed=self.sample_seed)

    def paged(self):
        """Project onto the paged engine's PagedEngineConfig."""
        from repro.serving.engine import PagedEngineConfig
        buckets = tuple(b for b in self.prefill_buckets if b <= self.max_seq)
        return PagedEngineConfig(
            batch_slots=self.batch_slots, max_seq=self.max_seq,
            page_tokens=self.page_tokens, hot_pages=self.hot_pages,
            prefill_buckets=buckets or (self.max_seq,),
            max_active_tokens=self.max_active_tokens,
            preload_distance=self.preload_distance,
            share_prefix_pages=self.share_prefix_pages,
            use_pallas_gather=self.use_pallas_gather,
            use_paged_kernel=self.use_paged_kernel,
            sweep_decode=self.sweep_decode,
            policy=self.policy,
            prefill_chunk_tokens=self.prefill_chunk_tokens,
            greedy=self.greedy, sample_seed=self.sample_seed,
            shadow_check=self.shadow_check)

    def scheduler(self):
        """Project onto the admission scheduler's SchedulerConfig (the
        same derivation PagedServingEngine applies internally)."""
        from repro.serving.scheduler import SchedulerConfig
        p = self.paged()
        return SchedulerConfig(
            prefill_buckets=p.prefill_buckets,
            max_active_tokens=(p.max_active_tokens
                               or p.batch_slots * p.max_seq),
            page_tokens=p.page_tokens, policy=p.policy, max_seq=p.max_seq)

    def pages(self):
        """Project onto the page pool's PageConfig (hot-frame sizing as
        PagedServingEngine derives it, reserved frames included)."""
        from repro.serving.kv_pages import PageConfig
        slot_pages = self.max_seq // self.page_tokens
        hot = self.hot_pages or (self.batch_slots * slot_pages + 2)
        return PageConfig(
            page_tokens=self.page_tokens, hot_frames=hot + 2,
            preload_distance=self.preload_distance,
            share_prefix_pages=self.share_prefix_pages,
            trace=self.shadow_check)

    # ------------------------------------------------------------------ #
    # the one flag surface
    # ------------------------------------------------------------------ #
    @staticmethod
    def add_flags(ap) -> None:
        """Register the serving knobs on an argparse parser (the flag
        names ``repro.launch.serve`` has always exposed)."""
        ap.add_argument("--slots", type=int, default=4)
        ap.add_argument("--max-seq", type=int, default=128)
        ap.add_argument("--page-tokens", type=int, default=16)
        ap.add_argument("--hot-pages", type=int, default=0)
        ap.add_argument("--distance", type=int, default=0,
                        help="page-restore preload distance (0 = planner d*)")
        ap.add_argument("--max-active-tokens", type=int, default=0)
        ap.add_argument("--no-prefix-sharing", action="store_true")
        ap.add_argument("--paged-kernel", action="store_true",
                        help="kernel-true decode: attention streams straight "
                             "over page frames (no dense assembly)")
        ap.add_argument("--no-sweep", action="store_true",
                        help="with --paged-kernel: per-layer kernel launches "
                             "+ eager row scatter instead of the fused "
                             "single-sweep decode")
        ap.add_argument("--policy", default="fcfs",
                        choices=("fcfs", "priority", "slo-edf"),
                        help="admission policy; priority and slo-edf preempt "
                             "running requests (swap-out to the cold tier)")
        ap.add_argument("--prefill-chunk", type=int, default=0,
                        help="chunked prefill: page-aligned tokens per tick "
                             "for prompts longer than this (0 = monolithic)")

    @classmethod
    def from_flags(cls, args) -> "ServingConfig":
        """Build from a parsed argparse namespace (see :meth:`add_flags`).

        Unknown knobs keep their dataclass defaults, so a launcher that
        registers only a subset of the flags still gets a full config."""
        get = lambda name, default: getattr(args, name, default)
        max_seq = get("max_seq", 128)
        return cls(
            batch_slots=get("slots", 4),
            max_seq=max_seq,
            prefill_bucket=min(64, max_seq // 2),
            page_tokens=get("page_tokens", 16),
            hot_pages=get("hot_pages", 0),
            preload_distance=get("distance", 0) or None,
            max_active_tokens=get("max_active_tokens", 0),
            share_prefix_pages=not get("no_prefix_sharing", False),
            use_paged_kernel=get("paged_kernel", False),
            sweep_decode=not get("no_sweep", False),
            policy=get("policy", "fcfs"),
            prefill_chunk_tokens=get("prefill_chunk", 0),
            prefill_buckets=tuple(b for b in _BUCKET_LADDER
                                  if b <= max_seq) or (max_seq,),
        )

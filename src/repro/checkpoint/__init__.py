from repro.checkpoint.checkpoint import (
    CheckpointConfig,
    CheckpointManager,
)

__all__ = ["CheckpointConfig", "CheckpointManager"]

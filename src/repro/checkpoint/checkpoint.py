"""Asynchronous checkpointing — the paper's *unloading* at framework scale.

Exactly the Exp.-5 pattern one level up: results (optimizer state) are
snapshotted out of the hot path and flushed to persistent storage by a
background writer while compute continues; synchronization happens only when
correctness requires it (end of run, or before a restore), mirroring the
paper's "persisting results have relaxed timing constraints ... explicit
software synchronization when locks/indices are involved".

Layout (multi-host ready):
  <dir>/step_<N>.tmp/           written first
      shard_<host>.npz          this host's addressable shards, flattened
      manifest.json             pytree structure + shapes + step
  <dir>/step_<N>/               atomic rename after fsync == commit marker

Restore reshards to the current mesh via jax.device_put (elastic restart:
a 2-pod checkpoint restores onto a 1-pod mesh and vice versa).
"""
from __future__ import annotations

import dataclasses
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    keep: int = 3
    async_write: bool = True        # unload-style background flush


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


# numpy's savez rejects extension dtypes (bfloat16); round-trip as uint16
# bit-patterns, dtype recorded in the manifest
def _encode(h: np.ndarray) -> np.ndarray:
    if h.dtype == jnp.bfloat16:
        return np.asarray(h).view(np.uint16)
    return h


def _decode(h: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name == "bfloat16":
        return h.view(jnp.bfloat16)
    return h


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.dir = Path(cfg.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, state, *, block: bool = False):
        """Snapshot (device->host, synchronous & cheap) then unload
        (host->disk, async). Returns immediately unless block=True."""
        self.wait()                                  # one in-flight flush
        leaves, treedef = _flatten_with_paths(state)
        # snapshot: addressable shards only (works single- and multi-host)
        host_leaves = []
        for x in leaves:
            if isinstance(x, jax.Array):
                host_leaves.append(np.asarray(jax.device_get(x)))
            else:
                host_leaves.append(np.asarray(x))
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "shapes": [list(np.shape(h)) for h in host_leaves],
            "dtypes": [str(np.asarray(h).dtype) for h in host_leaves],
            "time": time.time(),
        }

        def _flush():
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                final = self.dir / f"step_{step:08d}"
                if final.exists():
                    return                           # already committed
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                np.savez(tmp / "shard_0.npz",
                         **{f"leaf_{i}": _encode(h)
                            for i, h in enumerate(host_leaves)})
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                tmp.rename(final)                    # atomic commit
                self._gc()
            except BaseException as e:  # pul-lint: disable=PUL105 — trampolined to wait()
                self._last_error = e

        if self.cfg.async_write and not block:
            self._writer = threading.Thread(target=_flush, daemon=True)
            self._writer.start()
        else:
            _flush()
            self._raise_if_failed()

    def wait(self):
        """The PRELOAD_WAIT of unloading: join the in-flight flush."""
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise RuntimeError(f"async checkpoint flush failed: {e}") from e

    # ------------------------------------------------------------------ #
    def _steps(self) -> List[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                      if p.is_dir() and p.name.startswith("step_")
                      and not p.name.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        s = self._steps()
        return s[-1] if s else None

    def _gc(self):
        steps = self._steps()
        for s in steps[: max(0, len(steps) - self.cfg.keep)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------ #
    def restore(self, step: Optional[int] = None, *, like=None,
                shardings=None) -> Tuple[int, Any]:
        """Load a committed checkpoint; reshard onto `shardings` if given
        (elastic restart onto a different mesh)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        data = np.load(d / "shard_0.npz")
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = [_decode(data[f"leaf_{i}"], manifest["dtypes"][i])
                  for i in range(len(data.files))]
        if like is None:
            raise ValueError("restore needs `like` (a pytree prototype)")
        _, treedef = jax.tree.flatten(like)
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(jnp.asarray(x), s), tree, shardings)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return step, tree

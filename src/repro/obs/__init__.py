"""Unified observability for the PUL serving stack.

Two pieces, both dependency-free (no jax — importable from tools and CI
jobs without a device runtime):

  - :mod:`repro.obs.tracer`  — structured tracing: synchronous spans,
    cross-scope async spans, instants, counters, on two clocks (wall µs
    for the serving engine, model time for the DMA twin), exported as
    Chrome/Perfetto trace-event JSON. ``NULL_TRACER`` is the default
    everywhere and makes the whole layer zero-overhead when off.
  - :mod:`repro.obs.metrics` — flat metrics registry (JSON + Prometheus
    text exporters) and the cache-economics accounting: bytes moved per
    token emitted per tier, and prefetch accuracy / timeliness / coverage
    for planned d* restores.
"""
from repro.obs.metrics import (
    MetricsRegistry,
    Sample,
    cache_economics,
    economics_into_registry,
    serving_roofline,
)
from repro.obs.tracer import (
    NULL_TRACER,
    NullTracer,
    TraceEvent,
    Tracer,
    load_chrome_trace,
    page_events_from_chrome,
    validate_chrome_trace,
)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "TraceEvent",
    "load_chrome_trace", "validate_chrome_trace", "page_events_from_chrome",
    "MetricsRegistry", "Sample", "cache_economics",
    "economics_into_registry", "serving_roofline",
]

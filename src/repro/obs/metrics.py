"""Flat metrics registry + the ROADMAP's cache-economics accounting.

:class:`MetricsRegistry` is a deliberately small, dependency-free metric
store — named scalar samples with optional labels — exportable as JSON (for
``BENCH_*.json`` reports) and as Prometheus text exposition format (for
scraping a long-lived serving process). It is *pull*-shaped: the engine
fills a fresh registry from its counters at snapshot time, so there is no
per-tick registry traffic on the hot path and an untraced run allocates
nothing here either.

:func:`cache_economics` is the ROADMAP "bytes moved per token emitted, per
tier" metric plus the prefetch-quality triple from the prefetching survey
(Shakerinava et al., PAPERS.md) applied to planned d* page restores:

  * **accuracy**   — fraction of preloaded (restored) pages that were read
    before being evicted again. The pool marks each restore and clears the
    mark at first read; a page evicted still-unread was a wasted preload.
  * **timeliness** — fraction of restore access latency the planned d*
    schedule hid (the DMA twin's modeled stall vs total restore time) —
    the paper's headline quantity, per serving run.
  * **coverage**   — fraction of cold-page demands served by a *planned*
    preload batch rather than an unplanned demand stall. Today every
    restore flows through ``ensure_hot``'s planned batch, so coverage is
    1.0 by construction; the counter exists so a future speculative d*
    planner that misses demands becomes visible, not invisible.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    """Sanitize to Prometheus metric-name charset."""
    return _NAME_RE.sub("_", name)


def _prom_label_value(value: Any) -> str:
    s = str(value)
    return s.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


@dataclasses.dataclass(frozen=True)
class Sample:
    name: str
    value: float
    labels: Tuple[Tuple[str, str], ...] = ()


class MetricsRegistry:
    """Named scalar samples with labels; JSON + Prometheus exporters."""

    def __init__(self) -> None:
        self._samples: "Dict[Tuple[str, Tuple[Tuple[str, str], ...]], float]" = {}
        self._help: Dict[str, str] = {}

    def set(self, name: str, value: float, *, help: str = "",
            **labels: Any) -> None:
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        self._samples[key] = float(value)
        if help:
            self._help[name] = help

    def inc(self, name: str, value: float = 1.0, **labels: Any) -> None:
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        self._samples[key] = self._samples.get(key, 0.0) + float(value)

    def get(self, name: str, **labels: Any) -> Optional[float]:
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        return self._samples.get(key)

    def samples(self) -> List[Sample]:
        return [Sample(name=n, value=v, labels=lbls)
                for (n, lbls), v in sorted(self._samples.items())]

    # ------------------------------------------------------------------ #
    def to_json(self) -> Dict[str, Any]:
        """Flat JSON: {name: [{labels: {...}, value: v}, ...]}."""
        out: Dict[str, Any] = {}
        for s in self.samples():
            out.setdefault(s.name, []).append(
                {"labels": dict(s.labels), "value": s.value})
        return out

    def dump_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (gauges; one line/sample)."""
        lines: List[str] = []
        last_name = None
        for s in self.samples():
            name = _prom_name(s.name)
            if name != last_name:
                if s.name in self._help:
                    lines.append(f"# HELP {name} {self._help[s.name]}")
                lines.append(f"# TYPE {name} gauge")
                last_name = name
            if s.labels:
                lbl = ",".join(f'{_prom_name(k)}="{_prom_label_value(v)}"'
                               for k, v in s.labels)
                lines.append(f"{name}{{{lbl}}} {s.value:g}")
            else:
                lines.append(f"{name} {s.value:g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def dump_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_prometheus())


# ---------------------------------------------------------------------- #
# cache economics
# ---------------------------------------------------------------------- #
def cache_economics(*, page_bytes: int, tokens_emitted: int,
                    pool_metrics) -> Dict[str, Any]:
    """Bytes moved per token emitted, per tier, + prefetch quality.

    `pool_metrics` is a ``serving.kv_pages.PoolMetrics``.

    Tier accounting (bytes, from the pool's own counters):
      * ``hot``  — traffic into/out of the fast tier: restores land here
        (in), evictions leave here (out), plus the pool's own scatter
        traffic ``bytes_hot_written`` (prefill page fills and decode row
        writes — they originate on-device but are real HBM write
        bandwidth).
      * ``cold`` — the spill tier: evictions land here (in), restores are
        read back out (out).
    """
    pm = pool_metrics
    tokens = max(tokens_emitted, 1)
    fills = getattr(pm, "bytes_hot_written", 0)
    tiers = {
        "hot": {
            "bytes_in": pm.page_faults * page_bytes + fills,
            "bytes_out": pm.evictions * page_bytes,
        },
        "cold": {
            "bytes_in": pm.evictions * page_bytes,
            "bytes_out": pm.page_faults * page_bytes,
        },
    }
    for t in tiers.values():
        t["bytes_moved"] = t["bytes_in"] + t["bytes_out"]
        t["bytes_per_token"] = t["bytes_moved"] / tokens

    useful = getattr(pm, "useful_preloads", 0)
    wasted = getattr(pm, "wasted_preloads", 0)
    planned = getattr(pm, "planned_preloads", 0)
    unplanned = getattr(pm, "unplanned_restores", 0)
    prefetch = {
        "accuracy": (useful / (useful + wasted)) if (useful + wasted) else 1.0,
        "timeliness": pm.modeled_latency_hidden,
        "coverage": (planned / (planned + unplanned))
                    if (planned + unplanned) else 1.0,
        "planned_preloads": planned,
        "unplanned_restores": unplanned,
        "useful_preloads": useful,
        "wasted_preloads": wasted,
    }
    return {
        "tokens_emitted": tokens_emitted,
        "page_bytes": page_bytes,
        "tiers": tiers,
        "prefetch": prefetch,
    }


def serving_roofline(*, econ: Dict[str, Any], n_params: int,
                     tokens_emitted: int, peak_flops: float,
                     hot_bw: float, cold_bw: float) -> Dict[str, Any]:
    """Achieved-vs-peak bandwidth per tier for a paged serving run.

    Roofline accounting over the :func:`cache_economics` byte counters:
    the modeled run time is the critical path of decode compute
    (``2 * n_params`` FLOPs/token against ``peak_flops``) and each tier's
    transfer time (``bytes_moved`` against that tier's peak bandwidth,
    compute/IO fully overlapped — the PUL preload assumption). Each tier's
    ``bw_fraction`` is the share of its peak bandwidth the run sustains
    over that critical path; the dominant term scores 1.0.

    Everything here derives from tick-deterministic pool counters and
    fixed hardware constants — NOT wall time — so the numbers are bitwise
    reproducible and safe to gate in CI against a checked-in baseline.
    """
    tokens = max(tokens_emitted, 1)
    t_compute = tokens * 2.0 * n_params / peak_flops
    peak = {"hot": hot_bw, "cold": cold_bw}
    t_tier = {tier: econ["tiers"][tier]["bytes_moved"] / peak[tier]
              for tier in ("hot", "cold")}
    t_model = max(t_compute, *t_tier.values())
    terms = {"compute": t_compute, **t_tier}
    tiers = {}
    for tier, bw in peak.items():
        moved = econ["tiers"][tier]["bytes_moved"]
        tiers[tier] = {
            "bytes_moved": moved,
            "bytes_per_token": econ["tiers"][tier]["bytes_per_token"],
            "peak_bw": bw,
            "achieved_bw": moved / t_model,
            "bw_fraction": t_tier[tier] / t_model,
        }
    return {
        "tokens_emitted": tokens_emitted,
        "n_params": n_params,
        "modeled": {"compute_s": t_compute, "hot_s": t_tier["hot"],
                    "cold_s": t_tier["cold"], "critical_path_s": t_model,
                    "dominant": max(terms, key=terms.get)},
        "tiers": tiers,
    }


def economics_into_registry(reg: MetricsRegistry, econ: Dict[str, Any],
                            **labels: Any) -> None:
    """Flatten a :func:`cache_economics` dict into registry samples."""
    for tier, t in econ["tiers"].items():
        for k in ("bytes_in", "bytes_out", "bytes_moved", "bytes_per_token"):
            reg.set(f"pul_cache_{k}", t[k], tier=tier,
                    help=f"cache-economics {k} per tier", **labels)
    for k in ("accuracy", "timeliness", "coverage"):
        reg.set(f"pul_prefetch_{k}", econ["prefetch"][k],
                help=f"prefetch {k} of planned d* restores", **labels)
    reg.set("pul_tokens_emitted", econ["tokens_emitted"], **labels)

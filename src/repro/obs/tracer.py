"""Structured tracing for the PUL serving stack.

One :class:`Tracer` instance is threaded through every layer — the paged
engine (tick/prefill/chunk/decode spans, request lifecycles), the admission
scheduler (decisions with their *reason*), the KV page pool (the
``analysis.events`` lifecycle trace bridged into the same stream), and the
DMA twin (per-channel FIFO occupancy, descriptor spans, back-pressure
stalls) — so a serving run produces ONE timeline that Perfetto / Chrome
``about:tracing`` can load directly (:meth:`Tracer.to_chrome`).

Design rules:

  * **Zero overhead when off.** :data:`NULL_TRACER` (the default everywhere)
    is ``enabled=False`` and every method is a no-op returning a shared null
    context; no event object, dict, or string is ever allocated on the
    untraced hot path. Callers that would build an args dict guard on
    ``tracer.enabled`` first.
  * **Two clocks.** Serving-side events carry a *wall* timestamp (µs since
    the tracer was created, monotonic ``perf_counter``) plus the engine
    *tick* in ``args``; DMA-twin events carry *model* time (the discrete-
    event simulator's clock, µs) on their own process track, offset so
    successive restore batches lay out sequentially. Perfetto renders both;
    they are different time bases and are labeled as such.
  * **Spans nest or they don't exist.** Synchronous spans come from
    ``with tracer.span(...)``; the explicit ``begin_span``/``end_span``
    pair exists for call sites that cannot use ``with`` but MUST balance
    within one function scope (lint rule PUL106 enforces this). Work that
    genuinely crosses scopes — a request's life from submit to last token,
    a slot's occupancy — uses *async* spans (``async_begin``/``async_end``,
    Chrome ``b``/``e`` phases keyed by id), which are exempt from PUL106 by
    design.

Events are plain dataclasses with JSON-safe args (tuples become lists,
``inf`` becomes the string ``"inf"``), so a trace survives export → parse →
replay; :func:`page_events_from_chrome` rebuilds the page-lifecycle
``PageEvent`` stream from an exported file, which the round-trip tests feed
back through the sanitizer's ``LifecycleChecker``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import time
from typing import Any, Dict, List, Optional, Tuple

# Chrome trace-event phases this tracer emits
PH_BEGIN = "B"          # synchronous span open
PH_END = "E"            # synchronous span close
PH_COMPLETE = "X"       # span with explicit ts + dur (DMA descriptors)
PH_INSTANT = "i"        # point event (decisions, preemptions, page events)
PH_COUNTER = "C"        # sampled counter (FIFO occupancy, pool gauges)
PH_ASYNC_BEGIN = "b"    # cross-scope span open (requests, slot occupancy)
PH_ASYNC_END = "e"      # cross-scope span close
PHASES = {PH_BEGIN, PH_END, PH_COMPLETE, PH_INSTANT, PH_COUNTER,
          PH_ASYNC_BEGIN, PH_ASYNC_END}

# process ids in the exported trace: serving-side tracks run on wall-clock
# microseconds; the DMA twin's tracks run on (offset) model time
PID_SERVING = 1
PID_DMA = 2


def _json_safe(value: Any) -> Any:
    """Args must survive json.dump -> json.load bit-for-bit: tuples become
    lists, non-finite floats become strings (Perfetto rejects Infinity)."""
    if isinstance(value, (tuple, list)):
        return [_json_safe(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _json_safe(v) for k, v in value.items()}
    if isinstance(value, float) and not math.isfinite(value):
        return "inf" if value > 0 else ("-inf" if value < 0 else "nan")
    return value


def _json_restore(value: Any) -> Any:
    """Inverse of :func:`_json_safe` for scalar sentinels (lists stay lists;
    page-event reconstruction re-tuples the fields that need it)."""
    if value == "inf":
        return math.inf
    if value == "-inf":
        return -math.inf
    if value == "nan":
        return math.nan
    return value


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One recorded event (1:1 with a Chrome trace-event JSON object)."""

    ph: str                         # phase (see PH_* above)
    track: str                      # logical track -> tid in the export
    name: str
    ts: float                       # microseconds on the track's clock
    tick: int                       # engine tick at emission (-1: n/a)
    dur: Optional[float] = None     # PH_COMPLETE only
    span_id: Optional[int] = None   # async phases only
    cat: str = ""                   # category ("decision", "page", ...)
    args: Optional[Dict[str, Any]] = None


class Tracer:
    """Append-only event recorder with Chrome/Perfetto export."""

    enabled: bool = True

    def __init__(self) -> None:
        self.events: List[TraceEvent] = []
        self._t0 = time.perf_counter()
        self._tick = -1

    # ------------------------------------------------------------------ #
    # clocks
    # ------------------------------------------------------------------ #
    def now_us(self) -> float:
        """Wall microseconds since tracer creation (monotonic)."""
        return (time.perf_counter() - self._t0) * 1e6

    def set_tick(self, tick: int) -> None:
        """Anchor subsequent events to engine tick `tick`."""
        self._tick = tick

    # ------------------------------------------------------------------ #
    # emission
    # ------------------------------------------------------------------ #
    def _emit(self, ph: str, track: str, name: str, *,
              ts: Optional[float] = None, dur: Optional[float] = None,
              span_id: Optional[int] = None, cat: str = "",
              args: Optional[Dict[str, Any]] = None) -> None:
        self.events.append(TraceEvent(
            ph=ph, track=track, name=name,
            ts=self.now_us() if ts is None else ts,
            tick=self._tick, dur=dur, span_id=span_id, cat=cat,
            args=_json_safe(args) if args else None))

    def begin_span(self, track: str, name: str, **args) -> None:
        """Open a synchronous span. MUST be balanced by `end_span` in the
        same function scope (PUL106); prefer `with tracer.span(...)`."""
        self._emit(PH_BEGIN, track, name, args=args or None)

    def end_span(self, track: str, name: str = "") -> None:
        self._emit(PH_END, track, name)

    @contextlib.contextmanager
    def span(self, track: str, name: str, **args):
        """Synchronous span as a context manager (the preferred form)."""
        self.begin_span(track, name, **args)
        try:
            yield
        finally:
            self.end_span(track, name)

    def complete(self, track: str, name: str, *, ts: float, dur: float,
                 cat: str = "", **args) -> None:
        """Span with explicit start/duration (model-time DMA descriptors)."""
        self._emit(PH_COMPLETE, track, name, ts=ts, dur=max(dur, 0.0),
                   cat=cat, args=args or None)

    def instant(self, track: str, name: str, *, cat: str = "",
                ts: Optional[float] = None, **args) -> None:
        self._emit(PH_INSTANT, track, name, ts=ts, cat=cat,
                   args=args or None)

    def counter(self, track: str, name: str, value: float, *,
                ts: Optional[float] = None) -> None:
        self._emit(PH_COUNTER, track, name, ts=ts,
                   args={"value": value})

    def async_begin(self, track: str, name: str, span_id: int,
                    *, cat: str = "async", **args) -> None:
        """Open a cross-scope span (request lifecycle, slot occupancy).
        Paired by (cat, span_id), not by call scope — exempt from PUL106."""
        self._emit(PH_ASYNC_BEGIN, track, name, span_id=span_id, cat=cat,
                   args=args or None)

    def async_end(self, track: str, name: str, span_id: int,
                  *, cat: str = "async", **args) -> None:
        self._emit(PH_ASYNC_END, track, name, span_id=span_id, cat=cat,
                   args=args or None)

    def decision(self, name: str, **args) -> None:
        """Scheduler/engine decision point (admission, rejection,
        preemption) with its machine-readable *reason* — the events
        `tools/trace_diff.py` aligns two runs on."""
        self.instant("sched", name, cat="decision", **args)

    def page_event(self, seq: int, clock: int, kind, fields: Dict[str, Any]):
        """Bridge one `analysis.events` page-lifecycle transition into the
        stream (kind is an EventKind; fields are the PageEvent fields)."""
        args = {"seq": seq, "clock": clock}
        for k, v in fields.items():
            if v is None or (isinstance(v, tuple) and not v):
                continue                    # drop empties: smaller traces
            args["page" if k == "pid" else k] = v
        self.instant("pages", getattr(kind, "value", str(kind)),
                     cat="page", **args)

    # ------------------------------------------------------------------ #
    # export
    # ------------------------------------------------------------------ #
    def _track_ids(self) -> Dict[str, Tuple[int, int]]:
        """Stable track -> (pid, tid) assignment; DMA-model tracks get
        their own process (their clock is simulator time, not wall)."""
        out: Dict[str, Tuple[int, int]] = {}
        tids = {PID_SERVING: 0, PID_DMA: 0}
        for ev in self.events:
            if ev.track not in out:
                pid = PID_DMA if ev.track.startswith("dma") else PID_SERVING
                tids[pid] += 1
                out[ev.track] = (pid, tids[pid])
        return out

    def to_chrome(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Export as a Chrome/Perfetto trace-event JSON object (and write
        it to `path` when given)."""
        tracks = self._track_ids()
        events: List[Dict[str, Any]] = []
        for pid, label in ((PID_SERVING, "serving (wall clock)"),
                           (PID_DMA, "dma-twin (model time)")):
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "ts": 0,
                           "args": {"name": label}})
        for track, (pid, tid) in tracks.items():
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "ts": 0, "args": {"name": track}})
        for ev in self.events:
            pid, tid = tracks[ev.track]
            obj: Dict[str, Any] = {
                "ph": ev.ph, "name": ev.name, "pid": pid, "tid": tid,
                "ts": ev.ts,
            }
            if ev.cat:
                obj["cat"] = ev.cat
            args = dict(ev.args) if ev.args else {}
            if ev.tick >= 0 and ev.ph != PH_COUNTER:
                # counters stay pure: every args key of a 'C' event renders
                # as its own series, and tick-as-a-series is noise
                args["tick"] = ev.tick
            if args:
                obj["args"] = args
            if ev.ph == PH_COMPLETE:
                obj["dur"] = ev.dur
            if ev.ph in (PH_ASYNC_BEGIN, PH_ASYNC_END):
                obj["id"] = ev.span_id
                obj.setdefault("cat", "async")
            if ev.ph == PH_INSTANT:
                obj["s"] = "t"
            events.append(obj)
        doc = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "format": "pul-trace-v1",
                "tracks": {t: {"pid": p, "tid": i}
                           for t, (p, i) in tracks.items()},
            },
        }
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


class NullTracer(Tracer):
    """The off switch: every method is a no-op; nothing is ever allocated.

    `enabled=False` lets hot paths skip building args dicts entirely; the
    shared null context makes `with tracer.span(...)` free of per-call
    allocation too."""

    enabled = False
    _NULL_CTX = contextlib.nullcontext()

    def __init__(self) -> None:          # no event list, no clock
        self.events = ()                 # immutable + empty: nothing recorded

    def now_us(self) -> float:
        return 0.0

    def set_tick(self, tick: int) -> None:
        pass

    def _emit(self, *a, **kw) -> None:
        pass

    def span(self, track: str, name: str, **args):
        return self._NULL_CTX

    def to_chrome(self, path: Optional[str] = None) -> Dict[str, Any]:
        raise RuntimeError("NullTracer records nothing; nothing to export")


NULL_TRACER = NullTracer()


# ---------------------------------------------------------------------- #
# load / validate / reconstruct
# ---------------------------------------------------------------------- #
def load_chrome_trace(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema-check an exported trace; returns human-readable errors
    (empty list = valid). Checks the Chrome trace-event contract Perfetto
    relies on: required keys per phase, known phases, numeric finite
    timestamps, balanced B/E per (pid, tid), paired async b/e per
    (cat, id), non-negative X durations."""
    errors: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["top level must be an object with a 'traceEvents' list"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    stacks: Dict[Tuple[int, int], List[str]] = {}
    async_open: Dict[Tuple[str, Any], int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event #{i}: not an object")
            continue
        ph = ev.get("ph")
        if ph == "M":
            continue                    # metadata: free-form
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                errors.append(f"event #{i} ({ph}): missing '{key}'")
        if ph not in PHASES:
            errors.append(f"event #{i}: unknown phase {ph!r}")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts):
            errors.append(f"event #{i}: non-finite ts {ts!r}")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == PH_BEGIN:
            stacks.setdefault(key, []).append(ev.get("name", ""))
        elif ph == PH_END:
            if not stacks.get(key):
                errors.append(f"event #{i}: 'E' with no open 'B' on "
                              f"pid/tid {key}")
            else:
                stacks[key].pop()
        elif ph == PH_COMPLETE:
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event #{i}: 'X' needs a dur >= 0, "
                              f"got {dur!r}")
        elif ph == PH_COUNTER:
            args = ev.get("args") or {}
            if not any(isinstance(v, (int, float))
                       for v in args.values()):
                errors.append(f"event #{i}: counter with no numeric args")
        elif ph in (PH_ASYNC_BEGIN, PH_ASYNC_END):
            if "id" not in ev:
                errors.append(f"event #{i}: async event missing 'id'")
            akey = (ev.get("cat", ""), ev.get("id"))
            delta = 1 if ph == PH_ASYNC_BEGIN else -1
            async_open[akey] = async_open.get(akey, 0) + delta
            if async_open[akey] < 0:
                errors.append(f"event #{i}: async 'e' before 'b' for "
                              f"{akey}")
    for key, stack in stacks.items():
        for name in stack:
            errors.append(f"span '{name}' on pid/tid {key} never closed")
    return errors


def page_events_from_chrome(doc: Dict[str, Any]):
    """Rebuild the `analysis.events` PageEvent stream from an exported
    trace (the bridge's inverse). The result replays through
    `analysis.sanitizer.LifecycleChecker` exactly like the pool's own
    trace — the round-trip tests assert the two agree."""
    from repro.analysis.events import EventKind, PageEvent
    out = []
    for ev in doc.get("traceEvents", ()):
        if ev.get("cat") != "page" or ev.get("ph") != PH_INSTANT:
            continue
        args = dict(ev.get("args") or {})
        kind = EventKind(ev["name"])
        shared_key = args.get("shared_key")
        if isinstance(shared_key, list):
            shared_key = tuple(
                tuple(x) if isinstance(x, list) else x for x in shared_key)
        out.append(PageEvent(
            seq=int(args["seq"]),
            clock=int(args["clock"]),
            kind=kind,
            pid=args.get("page"),
            frame=args.get("frame"),
            refcount=args.get("refcount"),
            deadline=(None if args.get("deadline") is None
                      else float(_json_restore(args["deadline"]))),
            cause=args.get("cause"),
            pinned=tuple(args.get("pinned") or ()),
            frames=tuple(args.get("frames") or ()),
            n_valid=args.get("n_valid"),
            shared_key=shared_key,
        ))
    out.sort(key=lambda e: e.seq)
    return out

"""Sharded token pipeline with PUL-style host->device preloading.

The framework-level mirror of the paper's preload loop: batches are produced
on host (synthetic LM stream or memory-mapped token files), and `prefetch
distance` batches are kept in flight to the devices ahead of the training
step — the training loop never blocks on H2D transfers, exactly as the PE
never blocks on scratchpad fills.

Determinism & fault tolerance: batch content is a pure function of
(seed, step); resuming after a crash is `skip_to(step)` — no state files
needed, no data repeated or skipped (the restart contract used by
checkpoint/restore).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    prefetch_distance: int = 2      # PUL distance, host->device
    pack_docs: bool = True
    token_files: Optional[tuple] = None   # memory-mapped .npy shards
    frontend_tokens: int = 0
    d_model: int = 0                # for frontend stub embeddings


class TokenPipeline:
    """Deterministic, resumable, prefetching batch source."""

    def __init__(self, cfg: DataConfig, shardings: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self.step = 0
        self.shardings = shardings
        self._mmaps = None
        if cfg.token_files:
            self._mmaps = [np.load(f, mmap_mode="r") for f in cfg.token_files]
            self._total = sum(m.shape[0] for m in self._mmaps)
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, cfg.prefetch_distance))
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------ #
    def _host_batch(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        if self._mmaps is not None:
            # sample contiguous windows from the mmap'd corpus
            m = self._mmaps[step % len(self._mmaps)]
            starts = rng.integers(0, max(1, m.shape[0] - S - 1), size=B)
            toks = np.stack([np.asarray(m[s : s + S + 1]) for s in starts])
        else:
            # synthetic Zipf-ish LM stream (documents separated by token 0)
            toks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
            toks = np.minimum(toks, cfg.vocab_size - 1).astype(np.int32)
            if cfg.pack_docs:
                doc_ends = rng.random((B, S + 1)) < 1.0 / 512
                toks = np.where(doc_ends, 0, toks)
        batch = {
            "tokens": toks[:, :S].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
            "loss_mask": np.ones((B, S), np.float32),
        }
        if cfg.frontend_tokens:
            batch["frontend_embeds"] = (
                rng.standard_normal((B, cfg.frontend_tokens, cfg.d_model))
                .astype(np.float32) * 0.02).astype(jnp.bfloat16)
        return batch

    def _put(self, batch_np):
        if self.shardings:
            batch = {k: jax.device_put(v, self.shardings.get(k))
                     for k, v in batch_np.items()}
        else:
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        return batch

    # ------------------------------------------------------------------ #
    def skip_to(self, step: int):
        """Resume point: deterministic, O(1)."""
        assert self._thread is None, "skip before starting the prefetcher"
        self.step = step

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            batch = self._host_batch(s)
            self._q.put((s, batch))
            s += 1

    def start(self):
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        while not self._q.empty():
            try:
                self._q.get_nowait()
            except queue.Empty:
                break

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._thread is None:
            # synchronous fallback (tests / simple loops)
            b = self._put(self._host_batch(self.step))
            self.step += 1
            return b
        s, batch_np = self._q.get()
        self.step = s + 1
        return self._put(batch_np)

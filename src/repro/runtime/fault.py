"""Fault tolerance, straggler mitigation, elastic rescale — 1000+ node posture.

Design (and what is actually exercised in this repo):

* **Crash/restart**: training state is (params, opt, step); checkpoints are
  atomic-commit and the data pipeline is a pure function of (seed, step), so
  restart = restore latest + `skip_to(step)` — no coordination files. The
  integration test kills a run mid-flight and verifies bit-identical
  continuation.
* **Heartbeats / failure detection**: `HeartbeatMonitor` tracks per-worker
  liveness with a deadline; in a real deployment the launcher feeds it from
  the coordination service (JAX distributed heartbeats); here it is driven
  by the trainer loop and unit tests.
* **Straggler detection**: robust z-score over a sliding window of step
  times (median/MAD); a persistent outlier marks the worker for eviction —
  on TPU pods the slow host drags every collective, so the mitigation is
  evict + elastic rescale, not work stealing.
* **Elastic rescale**: `rescale_plan(old, new)` computes the new mesh and
  the resharding strategy; because checkpoints restore with `shardings` of
  the *new* mesh (jax.device_put reshards), dropping from 2 pods to 1 is:
  detect -> checkpoint (or reuse last) -> relaunch single-pod -> restore.
  The dry-run proves both meshes compile every architecture.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class WorkerState:
    last_seen: float
    step_times: Deque[float]


class HeartbeatMonitor:
    def __init__(self, deadline_s: float = 60.0, window: int = 32):
        self.deadline_s = deadline_s
        self.window = window
        self.workers: Dict[str, WorkerState] = {}

    def beat(self, worker: str, step_time: Optional[float] = None,
             now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        st = self.workers.setdefault(
            worker, WorkerState(now, deque(maxlen=self.window)))
        st.last_seen = now
        if step_time is not None:
            st.step_times.append(step_time)

    def dead_workers(self, now: Optional[float] = None) -> List[str]:
        now = time.monotonic() if now is None else now
        return [w for w, st in self.workers.items()
                if now - st.last_seen > self.deadline_s]

    def stragglers(self, *, z_threshold: float = 4.0, min_samples: int = 8
                   ) -> List[str]:
        """Median/MAD outlier detection over recent step times."""
        all_medians = []
        per_worker = {}
        for w, st in self.workers.items():
            if len(st.step_times) >= min_samples:
                xs = sorted(st.step_times)
                per_worker[w] = xs[len(xs) // 2]
                all_medians.append(per_worker[w])
        if len(all_medians) < 2:
            return []
        xs = sorted(all_medians)
        med = xs[len(xs) // 2]
        mad = sorted(abs(x - med) for x in xs)[len(xs) // 2] or 1e-9
        return [w for w, m in per_worker.items()
                if (m - med) / (1.4826 * mad) > z_threshold]


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_mesh: Tuple[int, ...]
    new_mesh: Tuple[int, ...]
    new_axes: Tuple[str, ...]
    batch_scale: float              # keep tokens/step constant via accum
    action: str


def rescale_plan(n_pods_old: int, n_pods_new: int) -> RescalePlan:
    """Elastic plan when pods join/leave. Data-parallel scale changes; the
    in-pod (data, model) topology is fixed at (16, 16); global batch is
    preserved by scaling gradient-accumulation steps."""
    if n_pods_new < 1:
        raise ValueError("cannot rescale to zero pods")
    if n_pods_new == 1:
        mesh, axes = (16, 16), ("data", "model")
    else:
        mesh, axes = (n_pods_new, 16, 16), ("pod", "data", "model")
    old = (n_pods_old, 16, 16) if n_pods_old > 1 else (16, 16)
    return RescalePlan(
        old_mesh=old, new_mesh=mesh, new_axes=axes,
        batch_scale=n_pods_old / n_pods_new,
        action=("restore latest checkpoint with new-mesh shardings; "
                "multiply accum by batch_scale; data.skip_to(step)"),
    )

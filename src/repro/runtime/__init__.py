from repro.runtime.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    logical_to_spec,
    spec_tree,
    sharding_tree,
)
__all__ = [
    "DEFAULT_RULES", "ShardingRules", "logical_to_spec", "spec_tree",
    "sharding_tree",
]

"""Logical-axis sharding rules with divisibility-aware fallback.

Every tensor in the framework carries *logical* axis names ("embed", "heads",
"ff", "batch", ...). This module resolves them to mesh axes on the production
mesh ``(pod, data, model)``:

  * weights are 2D-sharded: FSDP (ZeRO-3) over ``("pod","data")`` on their
    d_model-sized dim, tensor-parallel over ``"model"`` on heads/ff/vocab/
    experts — so a 314B-param model spreads over all 512 chips;
  * activations are batch-sharded over ``("pod","data")``; KV caches and
    long-context decode additionally shard the sequence dim over ``"data"``
    (batch=1 at 500k tokens cannot use the data axis);
  * each rule is a *priority list*: the resolver picks the first candidate
    whose device count divides the dim and whose mesh axes are not already
    used by an earlier dim of the same tensor, else replicates. This is how
    awkward shapes (40 heads on a 16-way model axis, vocab 92553) stay
    runnable — they fall back to replication for that dim only, and the
    roofline report makes the cost visible (padding them is a recorded
    §Perf optimization, not a silent default).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# priority list per logical name; None means "replicate" and always succeeds
DEFAULT_RULES: Dict[str, Sequence[Axis]] = {
    # ---- weight dims ----
    "embed": (("pod", "data"), "data", None),   # FSDP / ZeRO-3 shard dim
    "ff": ("model", None),                  # tensor parallel
    "vocab": ("model", None),
    "heads": ("model", None),
    "kv_heads": ("model", None),
    "experts": ("model", None),             # expert parallel
    "dinner": ("model", None),              # mamba inner channels
    "head_dim": (None,),
    "state": (None,),                       # SSM state dim
    "conv": (None,),
    "lora": (None,),
    "kv_rank": (None,),                     # MLA compressed dims stay local
    "q_rank": (None,),
    "norm": (None,),
    # ---- activation dims ----
    "batch": (("pod", "data"), "data", None),
    "seq": (None,),
    "act_embed": (None,),
    "act_heads": ("model", None),
    "act_kv_heads": ("model", None),
    "act_ff": ("model", None),
    # KV cache: sequence shards over whichever axis the batch/head dims left
    # free — on GQA models with few kv heads (8 < 16-way model axis) the
    # model axis takes the sequence dim, keeping 32k x 128-batch caches
    # under HBM limits; decode attention then reduces over the model axis.
    "cache_seq": ("data", "model", None),
    "seq_model": ("model", None),           # remat-carry sequence sharding
    "cache_batch": (("pod", "data"), "data", None),
    "expert_cap": (None,),
    "codebooks": (None,),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Dict[str, Sequence[Axis]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )

    def with_overrides(self, **over: Sequence[Axis]) -> "ShardingRules":
        r = dict(self.rules)
        r.update(over)
        return ShardingRules(r)


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return math.prod(mesh.shape[a] for a in axis)


def _axis_names(axis: Axis) -> Tuple[str, ...]:
    if axis is None:
        return ()
    if isinstance(axis, str):
        return (axis,)
    return tuple(axis)


def logical_to_spec(
    logical: Sequence[Optional[str]],
    dims: Sequence[int],
    mesh: Mesh,
    rules: ShardingRules = ShardingRules(),
) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec.

    Left-to-right; a mesh axis is used at most once per tensor; a candidate
    is accepted only if its total device count divides the dim size.
    """
    if len(logical) != len(dims):
        raise ValueError(f"logical {logical} does not match rank of shape {dims}")
    used: set = set()
    out = []
    for name, dim in zip(logical, dims):
        picked: Axis = None
        for cand in rules.rules.get(name, (None,)) if name is not None else (None,):
            names = _axis_names(cand)
            if any(n not in mesh.shape for n in names):
                continue  # axis absent on this mesh (e.g. single-pod)
            if any(n in used for n in names):
                continue
            if dim % _axis_size(mesh, cand) != 0:
                continue
            picked = cand
            break
        used.update(_axis_names(picked))
        out.append(picked)
    # trailing Nones can be dropped, PartitionSpec pads implicitly
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x, logical: Sequence[Optional[str]], rules: ShardingRules = ShardingRules()):
    """with_sharding_constraint via logical names, using the ambient mesh.

    Identity when tracing outside any mesh (CPU unit tests); inside
    jax.set_mesh / Mesh context it resolves the same way weights do.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except AttributeError:      # very old jax
        return x
    if mesh is None or getattr(mesh, "empty", True):
        return x
    spec = logical_to_spec(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, spec)


def spec_tree(logical_tree, shape_tree, mesh: Mesh, rules: ShardingRules = ShardingRules()):
    """Map a pytree of logical-axis tuples + shapes to PartitionSpecs."""
    return jax.tree.map(
        lambda logical, shaped: logical_to_spec(logical, shaped.shape, mesh, rules),
        logical_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def sharding_tree(logical_tree, shape_tree, mesh: Mesh, rules: ShardingRules = ShardingRules()):
    specs = spec_tree(logical_tree, shape_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))

"""PUL core: the paper's contribution as a composable JAX/Pallas layer.

Public API:
  - PULConfig, IssueStrategy, MemoryTier, PEModel (pul.py)
  - PreloadStream, UnloadStream, pul_loop, ring_scratch (pipeline.py)
  - DMAEngine, StreamStats, speedup (dma.py)
  - plan_stream, optimal_distance, predicted_speedup (planner.py)
"""
from repro.core.pul import (
    DRAM,
    HBM,
    MICROBLAZE,
    NVM,
    PES,
    REMOTE_HBM,
    TIERS,
    TPU_LANE,
    TPU_SUBLANE,
    TPU_V5E_MXU,
    TPU_V5E_VPU,
    UPMEM_DPU,
    Direction,
    IssueStrategy,
    MemoryTier,
    PEModel,
    PULConfig,
    TransferRequest,
)
from repro.core.pipeline import (
    VMEM_BUDGET_BYTES,
    PreloadStream,
    UnloadStream,
    pul_loop,
    pul_streams,
    ring_scratch,
)
from repro.core.dma import (
    DMAEngine,
    KVPageWorkload,
    StreamStats,
    kv_page_latency_hidden,
    run_kv_page_workload,
    speedup,
)
from repro.core.planner import (
    Plan,
    choose_block_rows,
    kv_page_bytes,
    kv_page_flops,
    optimal_distance,
    plan_kv_page_stream,
    plan_stream,
    predicted_speedup,
    roofline_time,
)

__all__ = [
    "PULConfig", "IssueStrategy", "Direction", "MemoryTier", "PEModel",
    "TransferRequest", "DRAM", "NVM", "HBM", "REMOTE_HBM", "TIERS", "PES",
    "MICROBLAZE", "UPMEM_DPU", "TPU_V5E_VPU", "TPU_V5E_MXU",
    "TPU_LANE", "TPU_SUBLANE", "VMEM_BUDGET_BYTES",
    "PreloadStream", "UnloadStream", "pul_loop", "pul_streams", "ring_scratch",
    "DMAEngine", "StreamStats", "speedup",
    "KVPageWorkload", "run_kv_page_workload", "kv_page_latency_hidden",
    "Plan", "plan_stream", "optimal_distance", "choose_block_rows",
    "predicted_speedup", "roofline_time",
    "plan_kv_page_stream", "kv_page_bytes", "kv_page_flops",
]

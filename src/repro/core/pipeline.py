"""TPU realization of PUL (paper Listing 1) as a Pallas pipeline emitter.

The paper's programming model:

    PRELOAD_SET_SIZE(64);
    PRELOAD(src[i], scratch[slot]);   // async, non-blocking enqueue
    PRELOAD_WAIT();                   // status-register sync
    ... compute on scratch[...] ...
    UNLOAD(scratch[slot], dst, n);    // async write-back

maps onto TPU Pallas as: refs living in HBM (`pl.ANY` memory space), ring
buffers of VMEM scratch slots, `pltpu.make_async_copy(...).start()` as the
FIFO enqueue, and DMA-semaphore `.wait()` as the status-register poll. The
classes below package that into *streams*:

  * :class:`PreloadStream` — distance-d read pipeline HBM -> VMEM ring.
  * :class:`UnloadStream`  — write-back pipeline VMEM ring -> HBM, waited
    `slots` blocks behind production (Exp. 5).
  * :func:`pul_loop`       — the steady-state driver: warm-up per the issue
    strategy, then wait(i) / body(i) / issue(i+d).

Kernels in `repro.kernels` build on these; nothing here is kernel-specific.
All of it runs under `interpret=True` on CPU (how this repo validates) and
lowers to real TPU DMA ops on hardware.
"""
from __future__ import annotations

import math
from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pul import IssueStrategy, PULConfig

# Default VMEM budget we allow a kernel's PUL rings to claim. v5e VMEM is
# ~128 MiB; leave headroom for the compute body's operands and XLA spills.
VMEM_BUDGET_BYTES = 96 * 2**20


def ring_scratch(cfg: PULConfig, block_shape: Sequence[int], dtype) -> Tuple:
    """Scratch shapes for one stream: (VMEM ring, DMA semaphores).

    Pass the results inside `scratch_shapes=[...]` of `pl.pallas_call`; the
    kernel receives them as (buf, sems) positional scratch arguments.
    """
    slots = cfg.num_slots
    nbytes = slots * math.prod(block_shape) * jnp.dtype(dtype).itemsize
    if nbytes > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"PUL ring of {slots} x {tuple(block_shape)} x {jnp.dtype(dtype).name} "
            f"= {nbytes/2**20:.1f} MiB exceeds the VMEM budget "
            f"({VMEM_BUDGET_BYTES/2**20:.0f} MiB); shrink block_shape or distance"
        )
    return (
        pltpu.VMEM((slots, *block_shape), dtype),
        pltpu.SemaphoreType.DMA((slots,)),
    )


def _block_slice(ref, offsets, block_shape):
    idx = tuple(pl.ds(o, s) for o, s in zip(offsets, block_shape))
    return ref.at[idx] if idx else ref


class PreloadStream:
    """Distance-d preload pipeline: HBM ref -> VMEM ring (paper PRELOAD).

    Args:
      src: source ref in `pl.ANY`/HBM memory space.
      buf: VMEM ring scratch, shape (slots, *block_shape).
      sems: DMA semaphore array, shape (slots,).
      index_map: fn(i) -> element offsets of block i in `src` (one offset per
        `src` axis, len == len(block_shape); traced, may read SMEM scalars —
        this is how trace-driven random preloads work).
      cfg: the PUL knobs.
      n_blocks: total number of logical blocks in the stream (static).
    """

    def __init__(self, src, buf, sems, *, index_map, cfg: PULConfig, n_blocks: int):
        self.src = src
        self.buf = buf
        self.sems = sems
        self.index_map = index_map
        self.cfg = cfg
        self.n_blocks = n_blocks
        self.slots = cfg.num_slots
        self.block_shape = tuple(buf.shape[1:])

    def _copy(self, i):
        slot = jax.lax.rem(i, self.slots)
        src_blk = _block_slice(self.src, self.index_map(i), self.block_shape)
        return pltpu.make_async_copy(src_blk, self.buf.at[slot], self.sems.at[slot])

    def issue(self, i):
        """Non-blocking FIFO enqueue of block i (PRELOAD)."""
        self._copy(i).start()

    def issue_if_in_range(self, i):
        @pl.when(i < self.n_blocks)
        def _():
            self.issue(i)

    def wait(self, i):
        """Status-register sync for block i (PRELOAD_WAIT); returns the VMEM
        slot view holding the block."""
        self._copy(i).wait()
        return self.buf.at[jax.lax.rem(i, self.slots)]


class UnloadStream:
    """Write-back pipeline: VMEM ring -> HBM ref (paper UNLOAD, Exp. 5).

    Production protocol for block i:
        view = stream.slot(i)     # waits for the flush that last used this
                                  # slot (i - slots) to retire, then hands
                                  # out the VMEM view to write results into
        ... body writes view ...
        stream.issue(i)           # async flush of block i
    and `drain()` at the end (the final PRELOAD_WAIT of Listing 1).
    """

    def __init__(self, dst, buf, sems, *, index_map, cfg: PULConfig, n_blocks: int):
        self.dst = dst
        self.buf = buf
        self.sems = sems
        self.index_map = index_map
        self.cfg = cfg
        self.n_blocks = n_blocks
        self.slots = cfg.num_slots
        self.block_shape = tuple(buf.shape[1:])

    def _copy(self, i):
        slot = jax.lax.rem(i, self.slots)
        dst_blk = _block_slice(self.dst, self.index_map(i), self.block_shape)
        return pltpu.make_async_copy(self.buf.at[slot], dst_blk, self.sems.at[slot])

    def slot(self, i):
        """VMEM view for producing block i; enforces single-owner slot reuse."""
        j = i - self.slots
        @pl.when(j >= 0)
        def _():
            self._copy(j).wait()
        return self.buf.at[jax.lax.rem(i, self.slots)]

    def issue(self, i):
        self._copy(i).start()
        if self.cfg.unload_distance == 0:       # synchronous-flush baseline
            self._copy(i).wait()

    def drain(self, produced: Optional[int] = None):
        """Wait for every in-flight flush. `produced` = number of blocks
        issued so far (defaults to the stream's static n_blocks)."""
        n = self.n_blocks if produced is None else produced
        if self.cfg.unload_distance == 0:
            return
        first = max(0, n - self.slots) if isinstance(n, int) else jnp.maximum(0, n - self.slots)
        if isinstance(n, int):
            for j in range(first, n):
                self._copy(jnp.int32(j)).wait()
        else:
            def body(j, _):
                @pl.when(j >= first)
                def _w():
                    self._copy(j).wait()
                return 0
            jax.lax.fori_loop(0, n, body, 0)


def pul_loop(
    n_blocks: int,
    preloads: Sequence[PreloadStream],
    body: Callable,                      # body(i, views: list[Ref], carry) -> carry
    carry,
    cfg: PULConfig,
    *,
    unloads: Sequence[UnloadStream] = (),
    drain: bool = True,
):
    """The steady-state PUL driver (paper Listing 1 around the compute).

    Warm-up: BATCH fires the full distance-d window up-front; SEQUENTIAL
    fires it too (Listing 1 lines 1-3) but in the steady state issues block
    i+d *before* computing block i (`PL[i+d] -> compute[i]`), whereas BATCH
    issues after the compute — with 2d slots the batches double-buffer.

    `n_blocks` must be static (Python int). Returns the final carry.
    """
    if n_blocks <= 0:
        return carry
    d = min(cfg.distance, n_blocks)

    for s in preloads:
        for i in range(d):
            s.issue(jnp.int32(i))

    seq = cfg.strategy is IssueStrategy.SEQUENTIAL

    def step(i, carry):
        if seq:
            for s in preloads:
                s.issue_if_in_range(i + d)
        views = [s.wait(i) for s in preloads]
        carry = body(i, views, carry)
        if not seq:
            for s in preloads:
                s.issue_if_in_range(i + d)
        return carry

    carry = jax.lax.fori_loop(0, n_blocks, step, carry)
    if drain:
        for u in unloads:
            u.drain()
    return carry


def pul_streams(
    refs_bufs_sems: Sequence[Tuple],
    index_maps: Sequence[Callable],
    cfg: PULConfig,
    n_blocks: int,
) -> List[PreloadStream]:
    """Convenience constructor for several parallel preload streams."""
    return [
        PreloadStream(r, b, s, index_map=m, cfg=cfg, n_blocks=n_blocks)
        for (r, b, s), m in zip(refs_bufs_sems, index_maps)
    ]

"""Discrete-event model of the paper's custom DMA engine.

The paper (§2) builds a custom DMA engine on an Alveo U280: two 64-deep FIFO
queues (preload / unload), non-blocking enqueue via HW registers, completion
via a status register, attached to a 150 MHz MicroBlaze PE with 64 KiB BRAM
scratchpad. We cannot synthesize that on a TPU; instead this module is a
cycle-approximate *software twin* of the engine, used to

  1. reproduce the paper's Experiments 1, 3, 4, 5 (benchmarks/bench_exp*.py)
     with the paper's own latency constants (DRAM vs NVM via NVMulator), and
  2. calibrate `core.planner`, which picks preload distance / transfer size
     for the real Pallas kernels from the same queueing math.

Model fidelity (matches the paper's described HW):
  * each direction has ONE channel processing its FIFO in order. Outstanding
    requests *pipeline*: the wire (bandwidth) is the serial resource, while
    per-request access latency overlaps across queued requests — this
    memory-level parallelism is exactly why deeper preload distances help
    (paper Fig. 5) until the window covers the latency;
  * enqueue costs the PE `issue_cycles` (writing src/dst/size registers);
    *register-value buffering* (paper §2) makes repeat enqueues with an
    unchanged size cheaper (`issue_cycles_cached`);
  * the FIFO holds `fifo_depth` outstanding requests; enqueue to a full FIFO
    blocks the PE (the paper never hits this: practical distances < 16);
  * waiting polls the status register: time = max(0, completion - now).

Issue strategies (paper Exp. 3, Fig 5-D):
  * SEQUENTIAL — warm-up of d requests, then the steady state alternates
    `PL[i+d] -> compute[i]`;
  * BATCH — requests are fired in back-to-back batches of d, then the
    *previous* batch is consumed (keeps the serial DMA channel gap-free; the
    paper finds it >= sequential below the latency plateau).

Multi-PE scaling (Exp. 1/4) is modeled by the aggregate-bandwidth cap: K PEs
run the single-PE schedule independently until the sum of their streaming
demands saturates `tier.bandwidth` (the paper's system tops out at 8 GiB/s).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Tuple

from repro.core.pul import (
    Direction,
    IssueStrategy,
    MemoryTier,
    PEModel,
    PULConfig,
)
from repro.obs.tracer import NULL_TRACER


@dataclasses.dataclass
class _Channel:
    """One serial DMA channel with a FIFO queue.

    Instrumented for the invariant tests and the trace layer: `wire_log`
    records each request's (enqueue_time, wire_start, wire_end) interval —
    the wire is the serial resource, so intervals must never overlap;
    `occupancy_log` samples (time, outstanding) at every enqueue — the
    executed FIFO-occupancy track that `analysis.plan_verifier.
    diff_fifo_occupancy` diffs against the symbolic schedule;
    `max_outstanding` tracks the deepest the FIFO ever got (must stay <=
    fifo_depth) and `high_water_time` the model time it FIRST got there;
    `stalls` records (wanted, granted) back-pressure intervals where a full
    FIFO blocked the PE's enqueue.
    """

    tier: MemoryTier
    direction: Direction
    fifo_depth: int
    completions: List[float] = dataclasses.field(default_factory=list)
    wire_log: List[tuple] = dataclasses.field(default_factory=list)
    occupancy_log: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)
    stalls: List[Tuple[float, float]] = dataclasses.field(
        default_factory=list)
    max_outstanding: int = 0
    high_water_time: float = 0.0
    tracer: Any = NULL_TRACER           # repro.obs.Tracer (model-time track)
    track: str = "dma"
    ts_offset: float = 0.0              # model-time offset of this run in
                                        # the trace (batches lay out
                                        # sequentially, not on top of 0)
    _wire_busy_until: float = 0.0

    def enqueue(self, now: float, nbytes: int) -> float:
        """Enqueue at PE-time `now`; returns completion time of this request.

        Pipelined-channel model: the wire slot serializes (bytes/bandwidth),
        the access latency rides on top and overlaps with other requests.
        """
        # FIFO back-pressure: if fifo_depth requests are still pending at
        # `now`, the PE stalls until a slot frees up.
        wanted = now
        pending = sorted(c for c in self.completions if c > now)
        if len(pending) >= self.fifo_depth:
            now = pending[len(pending) - self.fifo_depth]
            self.stalls.append((wanted, now))
            if self.tracer.enabled:
                self.tracer.complete(
                    self.track, "backpressure", cat="stall",
                    ts=(self.ts_offset + wanted) * 1e6,
                    dur=(now - wanted) * 1e6)
        lat = (self.tier.read_latency if self.direction is Direction.PRELOAD
               else self.tier.write_latency)
        wire_start = max(now, self._wire_busy_until)
        self._wire_busy_until = wire_start + nbytes / self.tier.bandwidth
        done = self._wire_busy_until + lat
        self.completions.append(done)
        self.wire_log.append((now, wire_start, self._wire_busy_until))
        outstanding = 1 + sum(1 for c in self.completions[:-1] if c > now)
        if outstanding > self.max_outstanding:
            self.max_outstanding = outstanding
            self.high_water_time = now      # the occupancy high-water tick
        self.occupancy_log.append((now, outstanding))
        if self.tracer.enabled:
            off = self.ts_offset
            self.tracer.complete(
                self.track, self.direction.name, cat="descriptor",
                ts=(off + now) * 1e6, dur=(done - now) * 1e6,
                nbytes=nbytes, issue=now, complete=done)
            self.tracer.counter(self.track, f"{self.track}:occupancy",
                                outstanding, ts=(off + now) * 1e6)
        return done


@dataclasses.dataclass
class StreamStats:
    """Timeline statistics of one simulated kernel execution."""

    total_time: float
    compute_time: float          # PE time spent on useful compute
    issue_time: float            # PE time spent writing DMA registers
    stall_time: float            # PE time blocked on status-register waits
    bytes_in: int
    bytes_out: int

    @property
    def pe_utilization(self) -> float:
        return self.compute_time / self.total_time if self.total_time else 0.0

    @property
    def io_throughput(self) -> float:
        return (self.bytes_in + self.bytes_out) / self.total_time if self.total_time else 0.0

    @property
    def ipc(self) -> float:
        """Fraction of PE cycles retiring instructions (paper Fig 4-B; DMA
        register writes are real instructions, so they count)."""
        return (self.compute_time + self.issue_time) / self.total_time if self.total_time else 0.0


class DMAEngine:
    """The two-queue engine + PE timeline executor (paper Listing 1)."""

    def __init__(
        self,
        tier: MemoryTier,
        pe: PEModel,
        *,
        fifo_depth: int = 64,
        issue_cycles: int = 12,
        issue_cycles_cached: int = 4,
        wait_poll_cycles: int = 2,
        tracer=None,
    ):
        self.tier = tier
        self.pe = pe
        self.fifo_depth = fifo_depth
        self.issue_cycles = issue_cycles
        self.issue_cycles_cached = issue_cycles_cached
        self.wait_poll_cycles = wait_poll_cycles
        # trace layer (repro.obs): per-channel FIFO occupancy + descriptor
        # spans on model-time tracks; NULL_TRACER = zero overhead
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._trace_clock = 0.0     # model-time offset of the NEXT run's
                                    # events (successive run_stream batches
                                    # lay out sequentially in the trace)

    def _cyc(self, n: float) -> float:
        return n / self.pe.clock_hz

    # ------------------------------------------------------------------ #
    def run_stream(
        self,
        cfg: PULConfig,
        *,
        n_blocks: int,
        block_bytes: int,
        compute_flops_per_block: float,
        unload_bytes_per_block: int = 0,
        interleave: bool = True,
    ) -> StreamStats:
        """Execute the canonical PUL loop (Listing 1) over `n_blocks`.

        `interleave=False` is the paper's baseline: synchronous load ->
        compute -> synchronous flush, no overlap (the "no PL / 1 Tasklet"
        configuration of Experiment 1).

        The plan is statically verified before execution (coverage, issue
        ordering, FIFO discipline — `repro.analysis.plan_verifier`); a
        corrupted plan raises PlanError instead of simulating garbage.
        """
        # imported lazily: analysis.plan_verifier imports core.pul, and a
        # module-level import here would deadlock the package init cycle
        from repro.analysis.plan_verifier import verify_stream_plan
        verify_stream_plan(cfg, n_blocks=n_blocks, block_bytes=block_bytes,
                           engine_fifo_depth=self.fifo_depth)
        tr, off = self.tracer, self._trace_clock
        pre = _Channel(self.tier, Direction.PRELOAD, self.fifo_depth,
                       tracer=tr, track="dma/preload", ts_offset=off)
        unl = _Channel(self.tier, Direction.UNLOAD, self.fifo_depth,
                       tracer=tr, track="dma/unload", ts_offset=off)
        self.last_channels = (pre, unl)     # exposed for invariant tests
        t = 0.0
        compute_t = issue_t = stall_t = 0.0
        compute_per_block = self.pe.compute_time(compute_flops_per_block)

        def issue(ch: _Channel, nbytes: int, first: bool) -> float:
            nonlocal t, issue_t
            dt = self._cyc(self.issue_cycles if first else self.issue_cycles_cached)
            t += dt
            issue_t += dt
            return ch.enqueue(t, nbytes)

        def wait_until(done: float):
            nonlocal t, stall_t
            t += self._cyc(self.wait_poll_cycles)
            if done > t:
                stall_t += done - t
                if tr.enabled:
                    tr.complete("dma/pe", "stall", cat="stall",
                                ts=(off + t) * 1e6, dur=(done - t) * 1e6)
                t = done

        def consume(i: int, pre_done, unl_done):
            nonlocal t, compute_t
            wait_until(pre_done[i])
            if tr.enabled:
                tr.complete("dma/pe", "compute", cat="compute", block=i,
                            ts=(off + t) * 1e6,
                            dur=compute_per_block * 1e6)
            t += compute_per_block
            compute_t += compute_per_block
            if unload_bytes_per_block:
                # scratchpad slot reuse: block i reuses the unload buffer of
                # block i - slots; that flush must have retired first.
                j = i - cfg.num_slots
                if j >= 0:
                    wait_until(unl_done[j])
                unl_done[i] = issue(unl, unload_bytes_per_block, first=(i == 0))
                if cfg.unload_distance == 0:   # synchronous-flush baseline
                    wait_until(unl_done[i])

        def finish() -> StreamStats:
            """Close out the run: advance the trace clock so the next batch
            lays out after this one, and stamp each channel's occupancy
            high-water tick (the executed back-pressure evidence the plan
            verifier cross-checks against its modeled warning)."""
            if tr.enabled:
                for ch in (pre, unl):
                    if ch.occupancy_log:
                        tr.instant(
                            ch.track, "fifo-high-water", cat="fifo",
                            ts=(off + ch.high_water_time) * 1e6,
                            occupancy=ch.max_outstanding,
                            model_time=ch.high_water_time,
                            fifo_depth=ch.fifo_depth,
                            stalled_enqueues=len(ch.stalls))
                self._trace_clock = off + t
            return StreamStats(t, compute_t, issue_t, stall_t,
                               n_blocks * block_bytes,
                               n_blocks * unload_bytes_per_block)

        if not interleave:
            for i in range(n_blocks):
                wait_until(issue(pre, block_bytes, first=(i == 0)))
                t += compute_per_block
                compute_t += compute_per_block
                if unload_bytes_per_block:
                    wait_until(issue(unl, unload_bytes_per_block, first=(i == 0)))
            return finish()

        d = max(1, min(cfg.distance, n_blocks))
        pre_done = [0.0] * n_blocks
        unl_done = [0.0] * n_blocks

        if cfg.strategy is IssueStrategy.BATCH:
            # rounds of d: fire the next batch back-to-back, consume previous
            for i in range(min(d, n_blocks)):
                pre_done[i] = issue(pre, block_bytes, first=(i == 0))
            r = 0
            while r < n_blocks:
                for i in range(r + d, min(r + 2 * d, n_blocks)):
                    pre_done[i] = issue(pre, block_bytes, first=False)
                for i in range(r, min(r + d, n_blocks)):
                    consume(i, pre_done, unl_done)
                r += d
        else:
            # warm-up of d, then alternate PL[i+d] -> compute[i]
            for i in range(min(d, n_blocks)):
                pre_done[i] = issue(pre, block_bytes, first=(i == 0))
            for i in range(n_blocks):
                nxt = i + d
                if nxt < n_blocks:
                    pre_done[nxt] = issue(pre, block_bytes, first=False)
                consume(i, pre_done, unl_done)

        # drain the unload queue (final PRELOAD_WAIT of Listing 1)
        if unload_bytes_per_block and n_blocks:
            wait_until(max(unl_done))
        return finish()

    # ------------------------------------------------------------------ #
    def scale_to_pes(self, single: StreamStats, n_pes: int) -> StreamStats:
        """Aggregate-bandwidth model for K identical PEs (paper Exp. 1/4).

        Each PE replays the single-PE schedule; once the summed demand hits
        the tier bandwidth, execution time dilates by the saturation factor.
        """
        demand = single.io_throughput * n_pes
        dilation = max(1.0, demand / self.tier.bandwidth)
        return StreamStats(
            total_time=single.total_time * dilation,
            compute_time=single.compute_time,
            issue_time=single.issue_time,
            stall_time=single.stall_time + single.total_time * (dilation - 1.0),
            bytes_in=single.bytes_in,
            bytes_out=single.bytes_out,
        )


def speedup(engine: DMAEngine, cfg: PULConfig, **kw) -> float:
    """PUL speedup vs the paper's phase-separated baseline."""
    base = engine.run_stream(cfg, interleave=False, **kw)
    pul = engine.run_stream(cfg, interleave=True, **kw)
    return base.total_time / pul.total_time


# --------------------------------------------------------------------------
# KV-page serving workload (paged-KV engine twin)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KVPageWorkload:
    """Steady-state decode over a paged KV cache, as seen by the DMA twin.

    Each decode step must restore `pages_per_step` cold pages from the slow
    tier while the PE runs attention over the pages already resident; the
    page restores are exactly the paper's preload stream (software knows the
    page list ahead of time — the access pattern is deterministic), so a
    distance-d window hides the restore latency behind per-page attention
    compute. Evicted pages leave through the unload channel.

    Attributes:
      page_bytes: bytes per KV page (page_tokens * packed features * dtype).
      flops_per_page: attention compute consuming one page during one decode
        step (scores + weighted sum over the page's tokens).
      pages_per_step: cold pages restored per decode step.
      steps: decode steps simulated (pages stream back-to-back across steps:
        the engine pipelines restores for step s+1 behind step s's compute).
      unload_pages_per_step: dirty pages written back per step (0 for a
        read-only KV reuse pattern; >0 models eviction write-back).
    """

    page_bytes: int
    flops_per_page: float
    pages_per_step: int = 1
    steps: int = 64
    unload_pages_per_step: int = 0

    @property
    def n_pages(self) -> int:
        return self.pages_per_step * self.steps


def run_kv_page_workload(
    engine: DMAEngine,
    wl: KVPageWorkload,
    *,
    distance: int,
    strategy: IssueStrategy = IssueStrategy.BATCH,
    interleave: bool = True,
) -> StreamStats:
    """Run the paged-KV decode stream on the DMA twin."""
    unload = 0
    if wl.unload_pages_per_step:
        # amortize write-back over the restore stream
        unload = wl.page_bytes * wl.unload_pages_per_step // wl.pages_per_step
    cfg = PULConfig(distance=min(distance, engine.fifo_depth),
                    strategy=strategy, fifo_depth=engine.fifo_depth,
                    unload_distance=1)
    return engine.run_stream(
        cfg,
        n_blocks=wl.n_pages,
        block_bytes=wl.page_bytes,
        compute_flops_per_block=wl.flops_per_page,
        unload_bytes_per_block=unload,
        interleave=interleave,
    )


def kv_page_latency_hidden(engine: DMAEngine, wl: KVPageWorkload,
                           *, distance: int) -> float:
    """Fraction of page-restore *access latency* hidden at `distance`.

    The hideable quantity is the per-request access latency (the paper's
    point: bandwidth is a serial floor, latency pipelines away once the
    preload window covers it). We measure the PE stall the preload schedule
    removes relative to the phase-separated baseline, normalized by the
    total access latency of the stream:

        hidden = (stall_baseline - stall_pul) / (n_pages * read_latency)

    clamped to [0, 1] (overlap can also hide bandwidth time behind compute,
    pushing the raw ratio past 1). 1.0 = the PE never waits on a restore
    beyond the bandwidth floor; 0.0 = every restore pays its full latency.
    """
    base = run_kv_page_workload(engine, wl, distance=distance,
                                interleave=False)
    pul = run_kv_page_workload(engine, wl, distance=distance)
    latency_exposure = wl.n_pages * engine.tier.read_latency
    if latency_exposure <= 0:
        return 1.0
    saved = base.stall_time - pul.stall_time
    return max(0.0, min(1.0, saved / latency_exposure))

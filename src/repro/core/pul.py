"""PUL configuration and request descriptors.

This module defines the *software contract* of the paper's technique:

- :class:`PULConfig` — the tunable knobs the paper exposes (preload distance,
  transfer/block size, issue strategy, unload distance) plus TPU-specific
  realization details (number of VMEM slots, semaphore layout).
- :class:`TransferRequest` — one entry of the DMA engine's FIFO, mirroring the
  paper's HW-register interface (src addr, dst addr, size) in a form usable
  both by the Pallas emitter (`core.pipeline`) and the discrete-event model
  (`core.dma`).

The paper distinguishes *pre-loading* (slow memory -> scratchpad, ahead of
consumption) from *un-loading* (scratchpad -> slow memory, behind production).
Both directions share the descriptor type; direction is explicit.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Sequence, Tuple


class IssueStrategy(str, enum.Enum):
    """Issue orderings studied in the paper's Experiment 3 (Fig. 5-D).

    BATCH:      issue the full warm-up window of `distance` requests first,
                then enter the steady state (paper: "batch-wise execution").
    SEQUENTIAL: alternate one issue / one compute from the start
                (paper: "sequential interleaving").
    The paper finds BATCH >= SEQUENTIAL for I/O throughput below the latency
    plateau, converging above it; BATCH is therefore the default.
    """

    BATCH = "batch"
    SEQUENTIAL = "sequential"


class Direction(str, enum.Enum):
    PRELOAD = "preload"  # slow memory -> scratchpad
    UNLOAD = "unload"    # scratchpad  -> slow memory


# TPU VMEM/VREG native tile for fp32/bf16-class dtypes; transfers should be
# multiples of this to avoid relayout on the DMA path (the TPU analogue of the
# paper's "64B cache-line" granularity discussion in Experiment 4).
TPU_LANE = 128
TPU_SUBLANE = 8


@dataclasses.dataclass(frozen=True)
class PULConfig:
    """Knobs of the PUL engine (paper §2, Listing 1).

    Attributes:
      distance: preload distance `d` — number of blocks requested ahead of
        consumption. The paper's Exp. 3 plateaus at d≈16 for its latencies;
        on TPU the planner (`core.planner`) derives d from block latency vs
        per-block compute time.
      unload_distance: how many blocks behind production the unload wait
        trails (0 = synchronous flush, the paper's non-PUL baseline).
      block_shape: scratchpad-block shape (the paper's configurable transfer
        size, Exp. 4). Product * dtype.itemsize = bytes per request.
      strategy: issue ordering (Exp. 3, Fig 5-D).
      slots: number of scratchpad buffers. Defaults to 2*distance for BATCH
        (double-buffered batches: the next batch lands while the previous is
        consumed) and distance+1 for SEQUENTIAL (issue of block i+d starts
        before block i's slot is free).
      fifo_depth: capacity of the modeled DMA request queue (the paper's HW
        FIFO holds 64 requests); the emitter asserts distance <= fifo_depth.
    """

    distance: int = 4
    unload_distance: int = 1
    block_shape: Tuple[int, ...] = (TPU_SUBLANE, TPU_LANE)
    strategy: IssueStrategy = IssueStrategy.BATCH
    slots: Optional[int] = None
    fifo_depth: int = 64

    def __post_init__(self):
        if self.distance < 1:
            raise ValueError(f"preload distance must be >= 1, got {self.distance}")
        if self.distance > self.fifo_depth:
            raise ValueError(
                f"distance {self.distance} exceeds DMA FIFO depth {self.fifo_depth} "
                "(the paper's engine queues at most fifo_depth outstanding requests)"
            )
        if self.unload_distance < 0:
            raise ValueError("unload distance must be >= 0")
        if self.slots is not None and self.slots < self.distance:
            raise ValueError(
                f"slots ({self.slots}) must be >= distance ({self.distance}): "
                "a block must stay resident until it is consumed"
            )

    @property
    def num_slots(self) -> int:
        if self.slots is not None:
            return self.slots
        if self.strategy is IssueStrategy.BATCH:
            return 2 * self.distance
        return self.distance + 1

    def transfer_bytes(self, itemsize: int) -> int:
        return int(math.prod(self.block_shape)) * itemsize

    def replace(self, **kw) -> "PULConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class TransferRequest:
    """One FIFO entry of the (modeled) DMA engine.

    Mirrors the paper's register interface: physical src, dst, size. `issue_t`
    is filled in by the discrete-event model; `tag` identifies the logical
    block for the pipeline emitter.
    """

    direction: Direction
    src: int              # abstract address (block index * block bytes)
    dst: int
    nbytes: int
    tag: int = -1
    issue_t: float = 0.0


@dataclasses.dataclass(frozen=True)
class MemoryTier:
    """Latency/bandwidth model of one memory technology (paper Fig. 2).

    Values are per-request latency (seconds) and sustained bandwidth
    (bytes/second). Defaults below are the tiers used across benchmarks.
    """

    name: str
    read_latency: float
    write_latency: float
    bandwidth: float

    def transfer_time(self, nbytes: int, direction: Direction) -> float:
        lat = self.read_latency if direction is Direction.PRELOAD else self.write_latency
        return lat + nbytes / self.bandwidth


# Paper tiers (NDP experiments; §3 Experimental Setup): DRAM vs emulated NVM
# (350 ns read / 170 ns write), system bandwidth capped at 8 GiB/s.
DRAM = MemoryTier("dram", read_latency=100e-9, write_latency=100e-9, bandwidth=8 * 2**30)
NVM = MemoryTier("nvm", read_latency=350e-9, write_latency=170e-9, bandwidth=8 * 2**30)
# TPU tiers (target hardware of this repo): v5e HBM, and remote HBM reached
# over one ICI hop (plays the paper's "slower tier" role on real systems).
HBM = MemoryTier("hbm", read_latency=1.0e-6, write_latency=1.0e-6, bandwidth=819e9)
REMOTE_HBM = MemoryTier("remote_hbm", read_latency=3.0e-6, write_latency=3.0e-6, bandwidth=50e9)

TIERS = {t.name: t for t in (DRAM, NVM, HBM, REMOTE_HBM)}


@dataclasses.dataclass(frozen=True)
class PEModel:
    """Compute model of the weak PE (paper: 150 MHz MicroBlaze / 350 MHz DPU).

    `flops_per_cycle` captures scalar in-order issue (1 for the paper's PEs).
    For the TPU adaptation the per-core VPU/MXU rates are used instead by the
    planner; this class exists so the DMA simulator can replay the paper's
    numbers faithfully.
    """

    name: str
    clock_hz: float
    flops_per_cycle: float = 1.0

    def compute_time(self, flops: float) -> float:
        return flops / (self.clock_hz * self.flops_per_cycle)


MICROBLAZE = PEModel("microblaze", 150e6)           # NDP soft-core
UPMEM_DPU = PEModel("upmem_dpu", 350e6)             # PIM
TPU_V5E_VPU = PEModel("tpu_v5e_vpu", 940e6, flops_per_cycle=8 * 128 * 4)   # vector unit
TPU_V5E_MXU = PEModel("tpu_v5e_mxu", 940e6, flops_per_cycle=197e12 / 940e6)

PES = {p.name: p for p in (MICROBLAZE, UPMEM_DPU, TPU_V5E_VPU, TPU_V5E_MXU)}

"""Roofline-driven PUL planner (beyond-paper contribution).

The paper *sweeps* preload distance and transfer size experimentally (Exps.
3-4) and reports where the plateaus are. This module derives those settings
analytically from the same queueing model, so kernels self-configure:

Steady-state of a distance-d pipeline over blocks with per-block compute time
``T_c`` (PE) and per-request I/O time ``T_io = latency + bytes/bandwidth``
(serial DMA channel):

  * throughput-bound floor: a block cannot be consumed faster than
    ``max(T_c, bytes/bandwidth)`` — the roofline;
  * latency is hidden once the window covers it: ``d * T_c >= T_io``, i.e.
    ``d* = ceil(T_io / T_c)`` — the paper's observed plateau (d≈16 for its
    NVM latencies and SUM compute) falls out of this directly;
  * distances beyond d* only cost scratchpad space: diminishing returns,
    exactly Fig. 5-A.

Transfer-size choice trades per-request overhead amortization against ring
VMEM footprint: pick the largest block such that `slots * bytes` fits the
VMEM budget and the DMA stays tile-aligned ((8,128) multiples).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.pul import (
    IssueStrategy,
    MemoryTier,
    PEModel,
    PULConfig,
    TPU_LANE,
    TPU_SUBLANE,
)
from repro.core import pipeline as _pipeline


@dataclasses.dataclass(frozen=True)
class Plan:
    cfg: PULConfig
    t_compute_per_block: float
    t_io_per_block: float
    predicted_time_per_block: float
    bound: str                      # "compute" | "bandwidth" | "latency"

    @property
    def predicted_utilization(self) -> float:
        return self.t_compute_per_block / self.predicted_time_per_block


def optimal_distance(t_compute: float, t_io: float, *, fifo_depth: int = 64) -> int:
    """d* = ceil(T_io / T_c): smallest window that hides the I/O time."""
    if t_compute <= 0:
        return fifo_depth
    return max(1, min(fifo_depth, math.ceil(t_io / t_compute)))


def plan_stream(
    *,
    block_bytes: int,
    flops_per_block: float,
    tier: MemoryTier,
    pe: PEModel,
    fifo_depth: int = 64,
    strategy: IssueStrategy = IssueStrategy.BATCH,
    block_shape: Optional[Tuple[int, ...]] = None,
) -> Plan:
    """Pick (distance, slots) for one preload stream and predict its rate."""
    t_c = pe.compute_time(flops_per_block)
    t_bw = block_bytes / tier.bandwidth
    t_io = tier.read_latency + t_bw
    d = optimal_distance(t_c, t_io, fifo_depth=fifo_depth)
    per_block = max(t_c, t_bw, t_io / max(d, 1))
    if per_block == t_c:
        bound = "compute"
    elif per_block == t_bw:
        bound = "bandwidth"
    else:
        bound = "latency"
    cfg = PULConfig(
        distance=d,
        strategy=strategy,
        fifo_depth=fifo_depth,
        block_shape=block_shape or (TPU_SUBLANE, TPU_LANE),
    )
    return Plan(cfg, t_c, t_io, per_block, bound)


def choose_block_rows(
    row_bytes: int,
    *,
    slots: int,
    vmem_budget: int = _pipeline.VMEM_BUDGET_BYTES,
    max_rows: Optional[int] = None,
    align: int = TPU_SUBLANE,
) -> int:
    """Largest tile-aligned row count per block whose ring fits VMEM."""
    rows = max(align, (vmem_budget // (slots * row_bytes)) // align * align)
    if max_rows is not None:
        rows = min(rows, max(align, max_rows // align * align) if max_rows >= align else max_rows)
    return max(1, rows)


def kv_page_bytes(page_tokens: int, kv_features: int, itemsize: int = 2) -> int:
    """Bytes of one KV page: `page_tokens` tokens x `kv_features` packed
    per-token KV features (every attention layer's K and V concatenated —
    the paged engine's page layout) x bf16 by default."""
    return page_tokens * kv_features * itemsize


def kv_page_flops(page_tokens: int, kv_features: int, gqa_group: int = 1) -> float:
    """Decode-attention compute consuming one KV page in one step.

    Per query head group the scores (q . k^T) and the weighted sum (p . v)
    each do ~2 MACs per cached feature; all `gqa_group` query heads of a KV
    group ride the same page transfer (PUL's amortized transfer size), so
    compute scales with the group while bytes don't."""
    return 4.0 * page_tokens * kv_features * gqa_group


def plan_kv_page_stream(
    *,
    page_tokens: int,
    kv_features: int,
    tier: MemoryTier,
    pe: PEModel,
    gqa_group: int = 1,
    itemsize: int = 2,
    fifo_depth: int = 64,
    strategy: IssueStrategy = IssueStrategy.BATCH,
) -> Plan:
    """Plan the page-restore preload stream of the paged-KV serving engine.

    The unit block is one KV page; d* = ceil(T_io / T_c) is the number of
    pages the engine requests ahead of the attention step consuming them —
    the paper's preload distance applied to KV paging."""
    return plan_stream(
        block_bytes=kv_page_bytes(page_tokens, kv_features, itemsize),
        flops_per_block=kv_page_flops(page_tokens, kv_features, gqa_group),
        tier=tier,
        pe=pe,
        fifo_depth=fifo_depth,
        strategy=strategy,
    )


def roofline_time(flops: float, bytes_moved: float, tier: MemoryTier, pe: PEModel) -> float:
    """Ideal (perfectly overlapped) execution time — the roofline itself."""
    return max(pe.compute_time(flops), bytes_moved / tier.bandwidth)


def predicted_speedup(
    *,
    block_bytes: int,
    flops_per_block: float,
    tier: MemoryTier,
    pe: PEModel,
) -> float:
    """Interleaved vs phase-separated execution — the paper's Fig. 1 claim.

    Baseline (no PUL): every block pays T_io + T_c serially.
    PUL at d*: per-block cost max(T_c, T_bw).
    """
    t_c = pe.compute_time(flops_per_block)
    t_io = tier.read_latency + block_bytes / tier.bandwidth
    base = t_c + t_io
    pul = max(t_c, block_bytes / tier.bandwidth)
    return base / pul if pul > 0 else float("inf")

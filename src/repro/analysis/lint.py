"""AST-based jit-safety lint for the PUL codebase.

Generic style/correctness linting belongs to ``ruff`` (configured in
``pyproject.toml``); this pass carries only the *domain* rules — tracing
hazards that are legal Python but wrong (or silently catastrophic) inside
``jax.jit`` / Pallas code paths:

  PUL101 traced-branch       Python ``if``/``while`` on a traced value in a
                             jitted/kernel function. Trace-time control flow
                             silently bakes one branch into the compiled
                             artifact; use ``jnp.where``/``lax.cond``.
  PUL102 host-sync           ``.item()`` / ``.tolist()`` / ``float()`` /
                             ``int()`` / ``bool()`` / ``np.asarray()`` on a
                             traced value: forces a device sync (or a
                             ConcretizationTypeError) in the hot path.
  PUL103 nonstatic-blockspec A ``pl.BlockSpec`` block shape built from a
                             traced value — block shapes must be static.
  PUL104 mutable-default     Mutable default argument (shared across calls;
                             a classic aliasing bug, and jit caches make it
                             worse by baking the first call's value in).
  PUL105 swallowed-exception Bare ``except:`` / ``except BaseException``
                             without re-raise (eats KeyboardInterrupt and
                             SystemExit), or an ``except Exception`` whose
                             handler neither re-raises nor inspects the
                             exception — a silent swallow.
  PUL106 unbalanced-span     Unequal ``.begin_span(`` / ``.end_span(`` call
                             counts within one function scope: an exception
                             between them leaves the tracer's B/E stack
                             open and every later span mis-nests. Use
                             ``with tracer.span(...)``; work that genuinely
                             crosses scopes belongs on async spans
                             (``async_begin``/``async_end``), which pair by
                             id and are exempt.
  PUL107 non-donated-update  ``x.at[...].set(...)`` (or ``.add``/... ) where
                             ``x`` is a parameter of a jitted function that
                             the jit wrap does NOT donate
                             (``donate_argnums``/``donate_argnames``). XLA
                             cannot alias an undonated input, so the update
                             materializes a full copy of the buffer every
                             call — the exact hidden cost the zero-copy page
                             store exists to avoid. Donate the argument (and
                             stop using the caller's handle afterwards) or
                             update a value derived inside the function.
                             Pallas kernel bodies are exempt: Refs mutate in
                             place by construction.

Traced-vs-host classification is annotation-driven, not heuristic: a
parameter annotated ``jax.Array`` / ``jnp.ndarray`` is traced; any other
annotation (``np.ndarray``, ``int``, config dataclasses, ...) is host.
Unannotated parameters are assumed traced ONLY inside explicit jit/kernel
contexts (functions decorated/wrapped with ``jax.jit``, passed to
``pl.pallas_call``, or named ``*_kernel``); elsewhere precision comes from
the annotations — which is why the serving/planner public APIs are fully
annotated. Static accessors (``x.shape``, ``x.ndim``, ``x.dtype``,
``len(x)``, ``isinstance(x, ...)``, ``x is None``) never count as traced
*uses*: shapes and dtypes are static under tracing.

Waive a true-but-intended finding with an inline comment on the flagged
line: ``# pul-lint: disable=PUL101`` (comma-separated list, or ``all``).
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "PUL101": "Python branch on a traced value in a jit/kernel context",
    "PUL102": "host sync on a traced value in a jit/kernel context",
    "PUL103": "non-static BlockSpec block shape",
    "PUL104": "mutable default argument",
    "PUL105": "swallowed exception",
    "PUL106": "unbalanced tracer span begin/end",
    "PUL107": "non-donated buffer update in a jitted function",
}

_WAIVER_RE = re.compile(r"#\s*pul-lint:\s*disable=([A-Za-z0-9,_\s]+|all)")

# annotations that mean "this value is traced under jit"
_TRACED_ANNOTATIONS = {
    "jax.Array", "Array", "jnp.ndarray", "jax.numpy.ndarray", "ndarray",
    "chex.Array", "ArrayLike", "jax.typing.ArrayLike",
}
# attribute reads that are static at trace time (never a traced *use*)
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "at"}
# calls whose result is host-static regardless of traced arguments
_STATIC_CALLS = {"len", "isinstance", "type", "hasattr", "getattr", "id",
                 "repr", "str"}
# module prefixes whose call results are traced arrays inside a jit context
_ARRAY_MODULES = ("jnp", "lax", "pl", "pltpu")
_HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_HOST_SYNC_CALLS = {"float", "int", "bool", "complex"}
_NUMPY_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray",
                     "numpy.array", "onp.asarray", "onp.array"}
_JIT_WRAPPERS = {"jax.jit", "jit", "jax.pmap", "pmap"}
_KERNEL_WRAPPERS = {"pl.pallas_call", "pallas_call", "pltpu.pallas_call"}
# `.at[...]` update methods whose result is a full functional copy of the
# base buffer unless XLA can alias it (donated input / internal value)
_AT_UPDATE_METHODS = {"set", "add", "subtract", "multiply", "divide",
                      "min", "max", "power", "apply"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def describe(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.jit' for Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _annotation_name(node: Optional[ast.AST]) -> Optional[str]:
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Subscript):        # Optional[jax.Array] etc.
        return _annotation_name(node.slice)
    return _dotted(node)


def _is_traced_annotation(node: Optional[ast.AST]) -> bool:
    name = _annotation_name(node)
    return name is not None and name in _TRACED_ANNOTATIONS


class _TracedUses(ast.NodeVisitor):
    """Collect *dynamic* uses of traced names inside one expression.

    A traced name consumed only through static accessors (``x.shape``,
    ``len(x)``, ``x is None``) contributes nothing — those are resolved at
    trace time and are safe in Python control flow.
    """

    def __init__(self, traced: Set[str]):
        self.traced = traced
        self.uses: List[ast.Name] = []

    def visit_Name(self, node: ast.Name) -> None:
        if node.id in self.traced:
            self.uses.append(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if node.attr in _STATIC_ATTRS:
            return                      # x.shape / x.dtype: static
        self.visit(node.value)

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if name in _STATIC_CALLS:
            return                      # len(x), isinstance(x, ...): static
        if name in _HOST_SYNC_CALLS or (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in _HOST_SYNC_METHODS):
            return                      # float(x) / x.item(): the RESULT is
                                        # a host scalar (the sync itself is
                                        # PUL102's business, inside jit)
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return                      # `x is None`: trace-time identity
        self.generic_visit(node)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        return                          # separate scope, analyzed on its own


def _dynamic_uses(expr: ast.AST, traced: Set[str]) -> List[ast.Name]:
    v = _TracedUses(traced)
    v.visit(expr)
    return v.uses


def _expr_is_traced(expr: ast.AST, traced: Set[str], in_jit: bool) -> bool:
    """Does evaluating `expr` yield a traced value?"""
    if _dynamic_uses(expr, traced):
        return True
    if in_jit and isinstance(expr, ast.Call):
        name = _dotted(expr.func) or ""
        head = name.split(".", 1)[0]
        if head in _ARRAY_MODULES or name.startswith("jax."):
            return True                 # jnp.zeros(...) etc. -> array
    return False


def _const_ints(node: Optional[ast.AST]) -> Set[int]:
    """Integer constants in a literal (or literal tuple/list/set)."""
    if node is None:
        return set()
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out: Set[int] = set()
        for elt in node.elts:
            out |= _const_ints(elt)
        return out
    return set()


def _const_strs(node: Optional[ast.AST]) -> Set[str]:
    if node is None:
        return set()
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out: Set[str] = set()
        for elt in node.elts:
            out |= _const_strs(elt)
        return out
    return set()


def _donation_kwargs(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    """donate_argnums / donate_argnames literals on a jit(...) call."""
    argnums: Set[int] = set()
    argnames: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            argnums |= _const_ints(kw.value)
        elif kw.arg == "donate_argnames":
            argnames |= _const_strs(kw.value)
    return argnums, argnames


class _FunctionLinter:
    """Lint one function body (not recursing into nested scopes)."""

    def __init__(self, fn, *, path: str, in_jit: bool,
                 findings: List[Finding], donated: Optional[Set[str]] = None,
                 check_donation: bool = False):
        self.fn = fn
        self.path = path
        self.in_jit = in_jit
        self.findings = findings
        self.donated = donated or set()
        self.check_donation = check_donation
        args = fn.args
        self.param_names = {
            a.arg for a in (list(args.posonlyargs) + list(args.args)
                            + list(args.kwonlyargs))
            if a.arg not in ("self", "cls")}
        self.traced = self._initial_traced(fn)

    # -------------------------------------------------------------- #
    def _initial_traced(self, fn) -> Set[str]:
        traced: Set[str] = set()
        args = fn.args
        positional = list(args.posonlyargs) + list(args.args)
        for a in positional:
            if _is_traced_annotation(a.annotation):
                traced.add(a.arg)
            elif a.annotation is None and self.in_jit and a.arg != "self":
                traced.add(a.arg)       # conservative fallback, jit only
        # keyword-only params of kernels are static partial-bound knobs;
        # trust annotations either way
        for a in args.kwonlyargs:
            if _is_traced_annotation(a.annotation):
                traced.add(a.arg)
        return traced

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.path, line=node.lineno,
            col=node.col_offset, message=message))

    # -------------------------------------------------------------- #
    def run(self) -> None:
        body = self.fn.body if not isinstance(self.fn, ast.Lambda) \
            else [ast.Expr(value=self.fn.body)]
        for stmt in body:
            self._visit_stmt(stmt)

    def _visit_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return                      # nested scope: handled separately
        if isinstance(stmt, ast.Assign):
            if _expr_is_traced(stmt.value, self.traced, self.in_jit):
                for tgt in stmt.targets:
                    for name in ast.walk(tgt):
                        if isinstance(name, ast.Name):
                            self.traced.add(name.id)
            self._visit_expr(stmt.value)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                if (_is_traced_annotation(stmt.annotation)
                        or _expr_is_traced(stmt.value, self.traced,
                                           self.in_jit)):
                    if isinstance(stmt.target, ast.Name):
                        self.traced.add(stmt.target.id)
                self._visit_expr(stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            self._visit_expr(stmt.value)
        elif isinstance(stmt, (ast.If, ast.While)):
            self._check_branch(stmt)
            self._visit_expr(stmt.test)
            for s in stmt.body + stmt.orelse:
                self._visit_stmt(s)
        elif isinstance(stmt, ast.For):
            if _expr_is_traced(stmt.iter, self.traced, self.in_jit):
                for name in ast.walk(stmt.target):
                    if isinstance(name, ast.Name):
                        self.traced.add(name.id)
            self._visit_expr(stmt.iter)
            for s in stmt.body + stmt.orelse:
                self._visit_stmt(s)
        elif isinstance(stmt, ast.Try):
            for s in (stmt.body + stmt.orelse + stmt.finalbody
                      + [h for hh in stmt.handlers for h in hh.body]):
                self._visit_stmt(s)
        elif isinstance(stmt, ast.With):
            for s in stmt.body:
                self._visit_stmt(s)
        elif isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                self._visit_expr(stmt.value)
        # other statements (pass, raise, etc.): nothing traced to track

    # -------------------------------------------------------------- #
    def _check_branch(self, stmt) -> None:
        # outside jit contexts `self.traced` only holds annotation-traced
        # names (and values derived from them), so host code that branches
        # on genuinely-host values is never flagged
        uses = _dynamic_uses(stmt.test, self.traced)
        if uses:
            kind = "if" if isinstance(stmt, ast.If) else "while"
            names = ", ".join(sorted({u.id for u in uses}))
            self._flag("PUL101", stmt,
                       f"`{kind}` on traced value(s) {names}: trace-time "
                       "control flow bakes one branch into the compiled "
                       "artifact (use jnp.where / lax.cond / lax.while_loop)")

    def _visit_expr(self, expr: ast.AST) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                self._check_call(node)

    def _check_call(self, call: ast.Call) -> None:
        name = _dotted(call.func)
        # PUL103: BlockSpec shapes must be static (any context — precision
        # comes from annotations outside jit functions)
        if name is not None and name.split(".")[-1] == "BlockSpec":
            self._check_blockspec(call)
        if not self.in_jit:
            return
        if self.check_donation:
            self._check_at_update(call)
        # PUL102: host syncs on traced values
        if (isinstance(call.func, ast.Attribute)
                and call.func.attr in _HOST_SYNC_METHODS
                and _expr_is_traced(call.func.value, self.traced, False)):
            self._flag("PUL102", call,
                       f".{call.func.attr}() on a traced value forces a "
                       "host sync inside the jitted hot path")
            return
        if name in _HOST_SYNC_CALLS and call.args and \
                _dynamic_uses(call.args[0], self.traced):
            self._flag("PUL102", call,
                       f"{name}() on a traced value raises "
                       "ConcretizationTypeError (or syncs) under jit")
        elif name in _NUMPY_SYNC_CALLS and call.args and \
                _dynamic_uses(call.args[0], self.traced):
            self._flag("PUL102", call,
                       f"{name}() on a traced value pulls it to host "
                       "memory inside the jitted hot path")

    def _check_at_update(self, call: ast.Call) -> None:
        """PUL107: `x.at[...].set(...)` where `x` is a non-donated param of
        this jitted function. The functional update can only alias (update
        in place) when XLA owns the input buffer — i.e. the jit wrap
        donates it; otherwise every call pays a full copy of `x`."""
        if not (isinstance(call.func, ast.Attribute)
                and call.func.attr in _AT_UPDATE_METHODS):
            return
        sub = call.func.value
        if not (isinstance(sub, ast.Subscript)
                and isinstance(sub.value, ast.Attribute)
                and sub.value.attr == "at"
                and isinstance(sub.value.value, ast.Name)):
            return                      # only bare-name bases: `x.at[i].set`
        base = sub.value.value.id
        if base in self.param_names and base not in self.donated:
            self._flag("PUL107", call,
                       f"`{base}.at[...].{call.func.attr}(...)` updates a "
                       f"jit parameter that is not donated: XLA cannot "
                       "alias the input, so every call copies the whole "
                       "buffer. Donate it (donate_argnums/donate_argnames "
                       "at the jit site) or build the updated value inside "
                       "the function")

    def _check_blockspec(self, call: ast.Call) -> None:
        shape = None
        if call.args and not isinstance(call.args[0], ast.Lambda):
            shape = call.args[0]
        for kw in call.keywords:
            if kw.arg == "block_shape":
                shape = kw.value
        if shape is None or not isinstance(shape, (ast.Tuple, ast.List)):
            return
        uses = _dynamic_uses(shape, self.traced)
        if uses:
            names = ", ".join(sorted({u.id for u in uses}))
            self._flag("PUL103", call,
                       f"BlockSpec block shape depends on traced value(s) "
                       f"{names}: block shapes must be static")


# ------------------------------------------------------------------ #
# module-level pass
# ------------------------------------------------------------------ #
class _ModuleLinter(ast.NodeVisitor):
    def __init__(self, tree: ast.Module, path: str):
        self.tree = tree
        self.path = path
        self.findings: List[Finding] = []
        # fn name -> (donated argnums, donated argnames) across every jit
        # wrap site that names it (union: donated anywhere counts)
        self.jit_donations: Dict[str, Tuple[Set[int], Set[str]]] = {}
        self.jit_names = self._collect_jit_names(tree)

    # -------------------------------------------------------------- #
    def _collect_jit_names(self, tree: ast.Module) -> Set[str]:
        """Names of functions that end up inside jit/pallas_call wrappers,
        resolving one level of `x = functools.partial(f, ...)` aliasing,
        and recording each jit site's donate_argnums/donate_argnames
        (argnums shifted past a partial's bound positional args)."""
        alias: Dict[str, Tuple[str, int]] = {}   # name -> (inner, n_bound)

        def _resolve_partial(call: ast.Call) -> Optional[Tuple[str, int]]:
            fname = _dotted(call.func)
            if fname in ("functools.partial", "partial") and call.args:
                inner = _dotted(call.args[0])
                if inner:
                    return inner, len(call.args) - 1
            return None

        for node in ast.walk(tree):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)):
                resolved = _resolve_partial(node.value)
                if resolved:
                    alias[node.targets[0].id] = resolved
        jit: Set[str] = set()
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fname = _dotted(node.func)
            if fname not in _JIT_WRAPPERS | _KERNEL_WRAPPERS:
                continue
            for arg in node.args[:1]:
                target, shift = _dotted(arg), 0
                if target is None and isinstance(arg, ast.Call):
                    # jax.jit(functools.partial(f, ...), ...) inline
                    resolved = _resolve_partial(arg)
                    if resolved:
                        target, shift = resolved
                elif target is not None and target in alias:
                    target, shift = alias[target]
                if target is None:
                    continue
                jit.add(target)
                if fname in _JIT_WRAPPERS:
                    nums, names = _donation_kwargs(node)
                    have = self.jit_donations.setdefault(
                        target, (set(), set()))
                    have[0].update(n + shift for n in nums)
                    have[1].update(names)
        return jit

    def _is_jit_context(self, fn) -> bool:
        if isinstance(fn, ast.Lambda):
            return False                # handled at the jit call sites
        for deco in fn.decorator_list:
            name = _dotted(deco if not isinstance(deco, ast.Call)
                           else deco.func)
            if name in _JIT_WRAPPERS:
                return True
            if isinstance(deco, ast.Call) and _dotted(deco.func) in (
                    "functools.partial", "partial") and deco.args:
                if _dotted(deco.args[0]) in _JIT_WRAPPERS:
                    return True
        if fn.name in self.jit_names:
            return True
        # repo convention: Pallas kernel bodies are named *_kernel
        return fn.name == "kernel" or fn.name.endswith("_kernel")

    def _is_pallas_kernel(self, fn) -> bool:
        return fn.name == "kernel" or fn.name.endswith("_kernel")

    def _donated_params(self, fn) -> Set[str]:
        """Parameter NAMES the jit wrap donates, from call-site records
        plus decorator forms (@jax.jit(donate_argnums=...) and
        @functools.partial(jax.jit, donate_argnums=...))."""
        nums: Set[int] = set()
        names: Set[str] = set()
        rec = self.jit_donations.get(fn.name)
        if rec:
            nums |= rec[0]
            names |= rec[1]
        for deco in fn.decorator_list:
            if not isinstance(deco, ast.Call):
                continue
            head = _dotted(deco.func)
            if head in _JIT_WRAPPERS or (
                    head in ("functools.partial", "partial") and deco.args
                    and _dotted(deco.args[0]) in _JIT_WRAPPERS):
                n, s = _donation_kwargs(deco)
                nums |= n
                names |= s
        positional = [a.arg for a in (list(fn.args.posonlyargs)
                                      + list(fn.args.args))]
        return names | {positional[i] for i in nums if i < len(positional)}

    # -------------------------------------------------------------- #
    def run(self) -> List[Finding]:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._lint_function(node)
                self._check_mutable_defaults(node)
                self._check_span_balance(node)
            elif isinstance(node, ast.Lambda):
                pass                    # params traced only via jit wrap
            elif isinstance(node, ast.Try):
                self._check_handlers(node)
        # lambdas passed straight into jit wrappers
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) and _dotted(node.func) in (
                    _JIT_WRAPPERS | _KERNEL_WRAPPERS):
                for arg in node.args[:1]:
                    if isinstance(arg, ast.Lambda):
                        _FunctionLinter(arg, path=self.path, in_jit=True,
                                        findings=self.findings).run()
        return self.findings

    def _lint_function(self, fn) -> None:
        in_jit = self._is_jit_context(fn)
        _FunctionLinter(fn, path=self.path, in_jit=in_jit,
                        findings=self.findings,
                        donated=self._donated_params(fn) if in_jit else None,
                        # Pallas Refs mutate in place by construction; the
                        # donation question only exists at jit boundaries
                        check_donation=not self._is_pallas_kernel(fn),
                        ).run()

    # -------------------------------------------------------------- #
    def _check_mutable_defaults(self, fn) -> None:
        defaults = list(fn.args.defaults) + [
            d for d in fn.args.kw_defaults if d is not None]
        for d in defaults:
            mutable = isinstance(d, (ast.List, ast.Dict, ast.Set,
                                     ast.ListComp, ast.DictComp, ast.SetComp))
            if isinstance(d, ast.Call) and _dotted(d.func) in (
                    "list", "dict", "set", "bytearray"):
                mutable = True
            if mutable:
                self.findings.append(Finding(
                    rule="PUL104", path=self.path, line=d.lineno,
                    col=d.col_offset,
                    message=f"mutable default argument in {fn.name}(): "
                            "shared across calls; use None + in-body init"))

    def _check_span_balance(self, fn) -> None:
        """PUL106: `.begin_span(` / `.end_span(` counts must balance within
        one function scope (nested defs/lambdas are their own scopes).
        Async spans (`async_begin`/`async_end`) pair by id across scopes by
        design and are exempt."""
        begins = ends = 0
        first: Optional[ast.Call] = None
        stack: List[ast.AST] = list(fn.body)
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue                # separate scope, checked on its own
            if isinstance(node, ast.Call) and isinstance(node.func,
                                                         ast.Attribute):
                if node.func.attr == "begin_span":
                    begins += 1
                    first = first or node
                elif node.func.attr == "end_span":
                    ends += 1
                    first = first or node
            stack.extend(ast.iter_child_nodes(node))
        if begins != ends:
            anchor = first if first is not None else fn
            self.findings.append(Finding(
                rule="PUL106", path=self.path, line=anchor.lineno,
                col=anchor.col_offset,
                message=f"{fn.name}() opens {begins} sync span(s) but "
                        f"closes {ends}: an exception in between leaves the "
                        "trace's B/E stack open. Use `with tracer.span("
                        "...)`; cross-scope work belongs on async spans"))

    def _check_handlers(self, node: ast.Try) -> None:
        for h in node.handlers:
            caught = _dotted(h.type) if h.type is not None else None
            broad_base = h.type is None or caught == "BaseException"
            catches_exc = caught == "Exception"
            if not (broad_base or catches_exc):
                continue
            has_raise = any(isinstance(n, ast.Raise)
                            for n in ast.walk(ast.Module(body=h.body,
                                                         type_ignores=[])))
            if broad_base and not has_raise:
                what = "bare except" if h.type is None \
                    else "except BaseException"
                self.findings.append(Finding(
                    rule="PUL105", path=self.path, line=h.lineno,
                    col=h.col_offset,
                    message=f"{what} without re-raise swallows "
                            "KeyboardInterrupt/SystemExit; catch Exception "
                            "or re-raise"))
            elif catches_exc and not has_raise and not self._uses_exc(h):
                self.findings.append(Finding(
                    rule="PUL105", path=self.path, line=h.lineno,
                    col=h.col_offset,
                    message="except Exception swallowed silently (no "
                            "re-raise, exception never inspected/logged); "
                            "name the expected exception or log it"))

    @staticmethod
    def _uses_exc(h: ast.ExceptHandler) -> bool:
        if h.name is None:
            # no binding: the handler can still log via traceback/logging
            return any(
                isinstance(n, ast.Call) and (_dotted(n.func) or "").split(
                    ".")[0] in ("traceback", "logging", "log", "warnings")
                for n in ast.walk(ast.Module(body=h.body, type_ignores=[])))
        return any(isinstance(n, ast.Name) and n.id == h.name
                   for n in ast.walk(ast.Module(body=h.body,
                                                type_ignores=[])))


# ------------------------------------------------------------------ #
# entry points
# ------------------------------------------------------------------ #
def _waived_rules(source: str) -> Dict[int, Set[str]]:
    waivers: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _WAIVER_RE.search(line)
        if m:
            spec = m.group(1).strip()
            rules = (set(RULES) if spec == "all"
                     else {r.strip() for r in spec.split(",") if r.strip()})
            waivers[i] = rules
    return waivers


def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    """Lint one module's source text; returns unwaived findings."""
    tree = ast.parse(source, filename=path)
    findings = _ModuleLinter(tree, path).run()
    waivers = _waived_rules(source)
    kept = [f for f in findings
            if f.rule not in waivers.get(f.line, set())]
    return sorted(kept, key=lambda f: (f.path, f.line, f.col, f.rule))


def lint_file(path: Path) -> List[Finding]:
    return lint_source(path.read_text(), str(path))


def lint_paths(paths: Sequence[Path]) -> List[Finding]:
    """Lint every .py file under the given files/directories."""
    findings: List[Finding] = []
    for p in paths:
        p = Path(p)
        files: Iterable[Path] = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_file(f))
    return findings

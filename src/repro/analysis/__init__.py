"""Static analysis & sanitizers for the PUL serving stack.

Three checking layers, all pure Python (no jax dependency — they must be
importable from CI lint jobs and from the hot serving path without pulling
in a device runtime):

  - :mod:`repro.analysis.events`    — typed page-lifecycle event trace
    recorded by ``KVPagePool`` when ``PageConfig.trace=True``.
  - :mod:`repro.analysis.sanitizer` — replays a trace against the formal
    page-lifecycle state machine and reports violations with event-level
    provenance (refcount underflow/leak, use-after-evict, zero-frame
    writes, double restore, same-step evict/restore churn, deadline-order
    violations in eviction).
  - :mod:`repro.analysis.plan_verifier` — statically validates
    ``plan_stream`` / ``plan_kv_page_stream`` outputs (coverage, issue
    ordering, FIFO-depth discipline) before ``DMAEngine.run_stream``
    executes them.
  - :mod:`repro.analysis.lint`      — AST-based jit-safety lint for
    ``src/repro`` (traced-value control flow, host syncs in jitted code,
    non-static BlockSpec shapes, mutable defaults, swallowed exceptions).
"""
from repro.analysis.events import EventKind, PageEvent, TraceLog
from repro.analysis.plan_verifier import (
    PlanError,
    PlanReport,
    diff_fifo_occupancy,
    verify_kv_page_plan,
    verify_stream_plan,
)
from repro.analysis.sanitizer import (
    LifecycleChecker,
    LifecycleViolationError,
    Violation,
    check_page_trace,
    format_violations,
)

__all__ = [
    "EventKind", "PageEvent", "TraceLog",
    "LifecycleChecker", "LifecycleViolationError", "Violation",
    "check_page_trace", "format_violations",
    "PlanError", "PlanReport", "verify_stream_plan", "verify_kv_page_plan",
    "diff_fifo_occupancy",
]

"""Typed page-lifecycle events: the sanitizer's input format.

``KVPagePool`` emits one :class:`PageEvent` per state transition when
``PageConfig.trace=True`` (and emits nothing — not even a branch into a
logging call — when tracing is off, so the production hot path pays zero
overhead). The trace is an append-only log; :mod:`repro.analysis.sanitizer`
replays it against the formal lifecycle state machine.

Events deliberately carry *plain* data (ints, floats, tuples) — no jax
arrays, no references into the pool — so a trace can be pickled, diffed,
or replayed long after the pool is gone, and so constructing synthetic
traces for failing-by-construction fixtures is trivial.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, List, Optional, Tuple


class EventKind(str, enum.Enum):
    """Every observable transition of a page's lifecycle."""

    ALLOC = "alloc"          # fresh page enters the hot tier
    REF = "ref"              # refcount incremented (explicit or shared hit)
    UNREF = "unref"          # refcount decremented
    FREE = "free"            # refcount reached zero; page ceases to exist
    EVICT = "evict"          # hot -> cold (frame released)
    RESTORE = "restore"      # cold -> hot (frame reacquired)
    TOUCH = "touch"          # page named in a step's working set (LRU update)
    READ = "read"            # page's frame handed to a decode gather/kernel
    WRITE_PAGE = "write_page"    # whole-page fill (prefill rows)
    WRITE_ROWS = "write_rows"    # one-row-per-slot decode scatter (by frame)
    DEADLINE = "deadline"    # page tagged with its owner's deadline tick
    TICK = "tick"            # pool clock advanced (step boundary)


@dataclasses.dataclass(frozen=True)
class PageEvent:
    """One recorded lifecycle transition.

    Attributes:
      seq: position in the trace (unique, monotonically increasing).
      clock: pool clock at emission (same-clock events happened in one step).
      kind: the transition type.
      pid: page id, or None for page-less events (TICK, WRITE_ROWS).
      frame: physical hot frame involved, if any.
      refcount: page refcount AFTER the event (REF/UNREF/ALLOC).
      deadline: deadline tick carried by DEADLINE events.
      cause: EVICT provenance — "steal" (capacity eviction, must follow
        deadline-then-LRU victim order) or "explicit" (policy swap-out /
        pause, exempt from victim-order checks).
      pinned: page ids the evictor was told it must not touch (EVICT/steal).
      frames: physical frame per slot for WRITE_ROWS events.
      n_valid: valid row count for WRITE_PAGE events.
      shared_key: prefix-sharing key for ALLOC/REF events, when present.
      layer: which per-layer KV plane the event's frame identifier lives in
        (v2 layout: the hot tier is one array PER LAYER, so frame f exists
        once per plane). ``None`` means the event spans every plane at once
        — the claim/write covers the whole physical frame. The fused sweep
        commit emits one WRITE_ROWS per layer with ``layer`` set; the
        sanitizer keys frame ownership by ``(layer, frame)`` so a same-frame
        write in a DIFFERENT layer is not a collision while one in the SAME
        layer still is.
    """

    seq: int
    clock: int
    kind: EventKind
    pid: Optional[int] = None
    frame: Optional[int] = None
    layer: Optional[int] = None
    refcount: Optional[int] = None
    deadline: Optional[float] = None
    cause: Optional[str] = None
    pinned: Tuple[int, ...] = ()
    frames: Tuple[int, ...] = ()
    n_valid: Optional[int] = None
    shared_key: Optional[tuple] = None

    def describe(self) -> str:
        bits = [f"#{self.seq} t={self.clock} {self.kind.value}"]
        if self.pid is not None:
            bits.append(f"page={self.pid}")
        if self.frame is not None:
            bits.append(f"frame={self.frame}")
        if self.layer is not None:
            bits.append(f"layer={self.layer}")
        if self.refcount is not None:
            bits.append(f"refcount={self.refcount}")
        if self.cause is not None:
            bits.append(f"cause={self.cause}")
        if self.frames:
            bits.append(f"frames={list(self.frames)}")
        if self.deadline is not None:
            bits.append(f"deadline={self.deadline}")
        return " ".join(bits)


class TraceLog:
    """Append-only event log with monotonic sequence numbers.

    ``emit`` assigns ``seq`` itself so callers (including broken-by-design
    test drivers emitting synthetic events) can never produce a trace with
    ambiguous ordering.
    """

    def __init__(self) -> None:
        self.events: List[PageEvent] = []

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[PageEvent]:
        return iter(self.events)

    def emit(self, clock: int, kind: EventKind, **fields) -> PageEvent:
        ev = PageEvent(seq=len(self.events), clock=clock, kind=kind, **fields)
        self.events.append(ev)
        return ev

    def clear(self) -> None:
        self.events.clear()

    def for_page(self, pid: int) -> List[PageEvent]:
        """Provenance view: every event touching page ``pid``, in order."""
        return [e for e in self.events if e.pid == pid]

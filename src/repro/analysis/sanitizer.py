"""Page-lifecycle sanitizer: replay a trace against the formal state machine.

The lifecycle contract of ``serving.kv_pages.KVPagePool``:

  ALLOC ->  HOT --EVICT-->  COLD --RESTORE--> HOT ...  --FREE--> gone
             |                                   |
           READ / WRITE (hot only)          (no reads/writes while cold)

plus three cross-page invariants:

  * refcounts never go below zero, and every page is freed eventually;
  * capacity ("steal") evictions pick the victim with the LATEST deadline,
    then least-recently-used — a page racing its deadline is never spilled
    while a page with slack sits hot;
  * a page is never evicted and restored within the same pool clock step
    (the PR 2 churn bug class: an allocation stealing a frame the very
    step just restored).

:class:`LifecycleChecker` consumes events incrementally (so the engine's
``shadow_check`` mode stays O(new events) per tick) and reports each broken
invariant as a :class:`Violation` carrying the offending event, the page id,
and the page's full event history — the violation is visible at the point
of violation, not N ticks later as a token mismatch.

Violation rules (the ``Violation.rule`` vocabulary):

  refcount-underflow    unref of a freed/unknown page, or refcount < 0
  refcount-leak         page still alive when the trace is finalized
  use-after-evict       read/write of a page that is cold or freed
  write-to-non-hot-frame  row scatter into the reserved zero frame, a free
                        frame, or any frame not backing a hot page
  double-restore        restore of a page that is already hot
  double-evict          evict of a page that is already cold (or freed)
  evict-restore-churn   same page evicted and restored in one clock step
  deadline-order        steal eviction whose victim was not the
                        latest-deadline (then LRU) evictable page
  frame-collision       alloc/restore into an occupied or reserved frame
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.events import EventKind, PageEvent

# mirror serving.kv_pages without importing it (keeps this package jax-free)
ZERO_FRAME = 0
TRASH_FRAME = 1
RESERVED_FRAMES = 2

_HOT, _COLD, _FREED = "hot", "cold", "freed"


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken lifecycle invariant, with event-level provenance."""

    rule: str
    message: str
    event: PageEvent                    # the event AT which the break occurred
    pid: Optional[int] = None
    history: Tuple[PageEvent, ...] = ()  # the page's prior events, in order

    @property
    def seq(self) -> int:
        return self.event.seq

    @property
    def clock(self) -> int:
        return self.event.clock

    def describe(self) -> str:
        lines = [f"[{self.rule}] {self.message}",
                 f"    at event {self.event.describe()}"]
        if self.history:
            lines.append("    page history:")
            lines.extend(f"      {e.describe()}" for e in self.history)
        return "\n".join(lines)


class LifecycleViolationError(AssertionError):
    """Raised by shadow_check mode: the trace broke the lifecycle contract."""

    def __init__(self, violations: Sequence[Violation]):
        self.violations = list(violations)
        super().__init__(format_violations(self.violations))


def format_violations(violations: Sequence[Violation]) -> str:
    if not violations:
        return "no lifecycle violations"
    body = "\n".join(v.describe() for v in violations)
    return f"{len(violations)} page-lifecycle violation(s):\n{body}"


@dataclasses.dataclass
class _PageState:
    state: str                      # _HOT | _COLD | _FREED
    frame: Optional[int]
    refcount: int
    last_used: int
    layer: Optional[int] = None     # plane scope of the frame claim
    deadline: float = math.inf
    last_evict_clock: int = -1
    last_restore_clock: int = -1
    history: List[PageEvent] = dataclasses.field(default_factory=list)


class LifecycleChecker:
    """Stateful replay of a page-event trace; collects violations.

    Frame identifiers are scoped ``(layer, frame)``: the v2 per-layer page
    store holds one hot array PER LAYER, so the same frame number names a
    distinct row range in every plane. An event with ``layer=None`` claims
    or writes the WHOLE physical frame (every plane at once) — the pool's
    allocator works at that granularity — while per-layer events (the fused
    sweep commit's WRITE_ROWS) touch exactly one plane. Two pages may
    therefore coexist on one frame number in *different* layers without a
    collision, but a same-layer overlap (or any overlap with a whole-frame
    claim) is still flagged."""

    def __init__(self) -> None:
        self.pages: Dict[int, _PageState] = {}
        # hot frame -> {layer or None (whole frame): pid}
        self.frame_owner: Dict[int, Dict[Optional[int], int]] = {}
        self.violations: List[Violation] = []
        self._consumed = 0

    # ------------------------------------------------------------------ #
    def _flag(self, rule: str, ev: PageEvent, message: str,
              pid: Optional[int] = None) -> None:
        pid = pid if pid is not None else ev.pid
        hist: Tuple[PageEvent, ...] = ()
        if pid is not None and pid in self.pages:
            hist = tuple(self.pages[pid].history)
        self.violations.append(
            Violation(rule=rule, message=message, event=ev, pid=pid,
                      history=hist))

    def _page(self, ev: PageEvent) -> Optional[_PageState]:
        return self.pages.get(ev.pid) if ev.pid is not None else None

    def _owner_of(self, layer: Optional[int],
                  frame: int) -> Optional[int]:
        """Resolve the pid owning ``(layer, frame)``: a layer-scoped claim
        wins, falling back to the whole-frame (layer=None) owner."""
        owners = self.frame_owner.get(frame, {})
        if layer is not None and layer in owners:
            return owners[layer]
        return owners.get(None)

    def _claim_frame(self, ev: PageEvent, pid: int,
                     frame: Optional[int]) -> None:
        if frame is None:
            return
        layer = ev.layer
        owners = self.frame_owner.setdefault(frame, {})
        if frame < RESERVED_FRAMES:
            self._flag("frame-collision", ev,
                       f"page {pid} placed into reserved frame {frame}")
        else:
            # whole-frame claims conflict with every plane; a layer-scoped
            # claim only with its own plane or a whole-frame owner
            rivals = (owners.values() if layer is None else
                      [o for l, o in owners.items()
                       if l is None or l == layer])
            rival = next((o for o in rivals if o != pid), None)
            if rival is not None:
                scope = "" if layer is None else f" (layer {layer})"
                self._flag("frame-collision", ev,
                           f"frame {frame}{scope} already backs hot page "
                           f"{rival}")
        owners[layer] = pid

    def _release_frame(self, pid: int, frame: Optional[int],
                       layer: Optional[int] = None) -> None:
        owners = self.frame_owner.get(frame)
        if owners is None:
            return
        if layer is None:
            # whole-frame release drops every claim this pid holds here
            for l in [l for l, o in owners.items() if o == pid]:
                del owners[l]
        elif owners.get(layer) == pid:
            del owners[layer]
        if not owners:
            self.frame_owner.pop(frame, None)

    # ------------------------------------------------------------------ #
    def feed(self, events: Iterable[PageEvent]) -> List[Violation]:
        """Consume new events; returns the violations they introduced."""
        before = len(self.violations)
        for ev in events:
            self._step(ev)
        return self.violations[before:]

    def feed_log(self, log) -> List[Violation]:
        """Consume a TraceLog incrementally (only events not yet seen)."""
        new = log.events[self._consumed:]
        self._consumed = len(log.events)
        return self.feed(new)

    # ------------------------------------------------------------------ #
    def _step(self, ev: PageEvent) -> None:
        kind = ev.kind
        if kind is EventKind.TICK:
            return
        if kind is EventKind.WRITE_ROWS:
            self._check_write_rows(ev)
            return

        ps = self._page(ev)
        if kind is EventKind.ALLOC:
            if ps is not None and ps.state is not _FREED:
                self._flag("frame-collision", ev,
                           f"page {ev.pid} allocated twice")
            self.pages[ev.pid] = ps = _PageState(
                state=_HOT, frame=ev.frame,
                refcount=ev.refcount if ev.refcount is not None else 1,
                last_used=ev.clock, layer=ev.layer)
            self._claim_frame(ev, ev.pid, ev.frame)
            ps.history.append(ev)
            return

        if ps is None or ps.state is _FREED:
            gone = "freed" if ps is not None else "unknown"
            if kind is EventKind.UNREF:
                self._flag("refcount-underflow", ev,
                           f"unref of {gone} page {ev.pid}")
            elif kind in (EventKind.READ, EventKind.WRITE_PAGE):
                self._flag("use-after-evict", ev,
                           f"{kind.value} of {gone} page {ev.pid}")
            elif kind is EventKind.EVICT:
                self._flag("double-evict", ev,
                           f"evict of {gone} page {ev.pid}")
            elif kind is EventKind.RESTORE:
                self._flag("double-restore", ev,
                           f"restore of {gone} page {ev.pid}")
            # REF/TOUCH/DEADLINE on an unknown page: tracked pages only
            elif kind is EventKind.REF:
                self._flag("refcount-underflow", ev,
                           f"ref of {gone} page {ev.pid}")
            return

        ps.history.append(ev)
        handler = {
            EventKind.REF: self._on_ref,
            EventKind.UNREF: self._on_unref,
            EventKind.FREE: self._on_free,
            EventKind.EVICT: self._on_evict,
            EventKind.RESTORE: self._on_restore,
            EventKind.TOUCH: self._on_touch,
            EventKind.READ: self._on_read,
            EventKind.WRITE_PAGE: self._on_write_page,
            EventKind.DEADLINE: self._on_deadline,
        }[kind]
        handler(ev, ps)

    # ------------------------------------------------------------------ #
    def _on_ref(self, ev: PageEvent, ps: _PageState) -> None:
        ps.refcount += 1

    def _on_unref(self, ev: PageEvent, ps: _PageState) -> None:
        ps.refcount -= 1
        if ps.refcount < 0:
            self._flag("refcount-underflow", ev,
                       f"page {ev.pid} refcount fell to {ps.refcount}")

    def _on_free(self, ev: PageEvent, ps: _PageState) -> None:
        if ps.refcount > 0:
            self._flag("refcount-underflow", ev,
                       f"page {ev.pid} freed with refcount {ps.refcount} "
                       "still outstanding")
        self._release_frame(ev.pid, ps.frame, ps.layer)
        ps.state = _FREED
        ps.frame = None

    def _on_evict(self, ev: PageEvent, ps: _PageState) -> None:
        if ps.state is not _HOT:
            self._flag("double-evict", ev,
                       f"evict of page {ev.pid} which is already {ps.state}")
            return
        if ev.cause == "steal":
            self._check_victim_order(ev, ps)
        if ps.last_restore_clock == ev.clock:
            self._flag("evict-restore-churn", ev,
                       f"page {ev.pid} restored and evicted within clock "
                       f"step {ev.clock} (same-step churn)")
        ps.last_evict_clock = ev.clock
        self._release_frame(ev.pid, ps.frame, ps.layer)
        ps.state = _COLD
        ps.frame = None

    def _on_restore(self, ev: PageEvent, ps: _PageState) -> None:
        if ps.state is _HOT:
            self._flag("double-restore", ev,
                       f"restore of page {ev.pid} which is already hot in "
                       f"frame {ps.frame}")
            return
        if ps.last_evict_clock == ev.clock:
            self._flag("evict-restore-churn", ev,
                       f"page {ev.pid} evicted and restored within clock "
                       f"step {ev.clock} (same-step churn)")
        ps.last_restore_clock = ev.clock
        ps.state = _HOT
        ps.frame = ev.frame
        ps.layer = ev.layer
        self._claim_frame(ev, ev.pid, ev.frame)

    def _on_touch(self, ev: PageEvent, ps: _PageState) -> None:
        ps.last_used = ev.clock

    def _on_read(self, ev: PageEvent, ps: _PageState) -> None:
        if ps.state is not _HOT:
            self._flag("use-after-evict", ev,
                       f"read of page {ev.pid} which is {ps.state}")

    def _on_write_page(self, ev: PageEvent, ps: _PageState) -> None:
        if ps.state is not _HOT:
            self._flag("use-after-evict", ev,
                       f"write to page {ev.pid} which is {ps.state}")

    def _on_deadline(self, ev: PageEvent, ps: _PageState) -> None:
        if ev.deadline is not None:
            ps.deadline = ev.deadline

    # ------------------------------------------------------------------ #
    def _check_victim_order(self, ev: PageEvent, victim: _PageState) -> None:
        """A steal eviction must pick the latest-deadline, then least-
        recently-used, hot page outside the pinned working set."""
        pinned = set(ev.pinned)
        for pid, ps in self.pages.items():
            if pid == ev.pid or ps.state is not _HOT or pid in pinned:
                continue
            later = ps.deadline > victim.deadline
            tie_lru = (ps.deadline == victim.deadline
                       and ps.last_used < victim.last_used)
            if later or tie_lru:
                why = (f"deadline {ps.deadline} > {victim.deadline}" if later
                       else f"equal deadline but older last_used "
                            f"{ps.last_used} < {victim.last_used}")
                self._flag("deadline-order", ev,
                           f"steal evicted page {ev.pid} while page {pid} "
                           f"was the better victim ({why})")
                return

    def _check_write_rows(self, ev: PageEvent) -> None:
        where = "" if ev.layer is None else f" (layer {ev.layer})"
        for slot, frame in enumerate(ev.frames):
            if frame == TRASH_FRAME:
                continue                    # designated write sink: fine
            if frame == ZERO_FRAME:
                self._flag("write-to-non-hot-frame", ev,
                           f"slot {slot} scattered a row into the reserved "
                           "zero frame (unallocated page-table slots must "
                           "stay all-zeros)",
                           pid=self._owner_of(ev.layer, frame))
            elif self._owner_of(ev.layer, frame) is None:
                self._flag("write-to-non-hot-frame", ev,
                           f"slot {slot} scattered a row into frame "
                           f"{frame}{where} which backs no hot page")

    # ------------------------------------------------------------------ #
    def finalize(self) -> List[Violation]:
        """End-of-trace checks: every page must have been freed."""
        before = len(self.violations)
        for pid, ps in sorted(self.pages.items()):
            if ps.state is _FREED:
                continue
            last = ps.history[-1] if ps.history else PageEvent(
                seq=-1, clock=-1, kind=EventKind.ALLOC, pid=pid)
            self._flag("refcount-leak", last,
                       f"page {pid} never freed (refcount {ps.refcount}, "
                       f"state {ps.state}) — leaked at end of trace",
                       pid=pid)
        return self.violations[before:]


def check_page_trace(events: Iterable[PageEvent], *,
                     final: bool = False) -> List[Violation]:
    """One-shot replay: feed every event, optionally run end-of-trace
    (leak) checks, and return all violations found."""
    checker = LifecycleChecker()
    checker.feed(events)
    if final:
        checker.finalize()
    return checker.violations

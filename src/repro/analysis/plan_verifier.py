"""Static verification of PUL preload plans before execution.

``core.planner.plan_stream`` / ``plan_kv_page_stream`` emit a
:class:`~repro.core.pul.PULConfig` that ``DMAEngine.run_stream`` then
executes over ``n_blocks`` blocks. A malformed plan — distance outside the
FIFO window, an issue schedule that consumes a block before its preload was
ever requested, a schedule that skips blocks — silently produces wrong
timings (and, on real hardware, wrong *data*). This module validates a plan
purely statically: it derives the exact issue/consume order the engine will
use (mirroring the two ``IssueStrategy`` schedules symbolically, no
simulation clock involved) and checks

  * config sanity: distance >= 1, within both the plan's and the executing
    engine's FIFO depth; enough scratchpad slots to keep every in-flight
    block resident; non-negative unload distance; positive block size;
  * ordering: every consumed block's preload was issued (and, because the
    engine waits on the completion register before consuming, completed)
    strictly before its consume;
  * coverage: every block in [0, n_blocks) is consumed exactly once;
  * capacity: the deepest in-flight preload window never exceeds the
    scratchpad slot count, and FIFO overflow (BATCH's 2d window past the
    queue depth) is reported as a stall warning.

``DMAEngine.run_stream`` calls :func:`verify_stream_plan` as a
precondition; ``benchmarks/kv_page_dma.py`` verifies the planner's output
before sweeping it. Errors raise :class:`PlanError`; warnings ride along in
the returned :class:`PlanReport`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.pul import IssueStrategy, PULConfig


class PlanError(ValueError):
    """A preload plan failed static verification; executing it would break
    the FIFO/ordering contract (or read unfetched data on real hardware)."""


@dataclasses.dataclass(frozen=True)
class PlanReport:
    """Outcome of a static plan verification."""

    distance: int
    n_blocks: int
    max_in_flight: int              # deepest preload window in the schedule
    warnings: Tuple[str, ...] = ()

    @property
    def ok(self) -> bool:           # errors raise; a report means verified
        return True


def _schedule(cfg: PULConfig, n_blocks: int) -> List[Tuple[str, int]]:
    """The exact (op, block) order run_stream will execute, symbolically.

    Ops are ("issue", i) — preload of block i enqueued — and
    ("consume", i) — block i's compute, which waits on preload i first.
    Mirrors ``DMAEngine.run_stream``'s two strategies.
    """
    d = max(1, min(cfg.distance, n_blocks))
    sched: List[Tuple[str, int]] = []
    if cfg.strategy is IssueStrategy.BATCH:
        for i in range(min(d, n_blocks)):
            sched.append(("issue", i))
        r = 0
        while r < n_blocks:
            for i in range(r + d, min(r + 2 * d, n_blocks)):
                sched.append(("issue", i))
            for i in range(r, min(r + d, n_blocks)):
                sched.append(("consume", i))
            r += d
    else:
        for i in range(min(d, n_blocks)):
            sched.append(("issue", i))
        for i in range(n_blocks):
            nxt = i + d
            if nxt < n_blocks:
                sched.append(("issue", nxt))
            sched.append(("consume", i))
    return sched


def verify_stream_plan(
    cfg: PULConfig,
    *,
    n_blocks: int,
    block_bytes: int,
    engine_fifo_depth: Optional[int] = None,
) -> PlanReport:
    """Statically validate one preload plan; raises PlanError on violation.

    ``engine_fifo_depth`` is the FIFO depth of the engine that will execute
    the plan — a plan may carry a deeper ``cfg.fifo_depth`` than the
    hardware it lands on, which ``PULConfig.__post_init__`` cannot know.
    """
    if n_blocks < 0:
        raise PlanError(f"n_blocks must be >= 0, got {n_blocks}")
    if block_bytes <= 0:
        raise PlanError(f"block_bytes must be positive, got {block_bytes}")
    if not isinstance(cfg.strategy, IssueStrategy):
        raise PlanError(f"unknown issue strategy {cfg.strategy!r}")
    d = cfg.distance
    if d < 1:
        raise PlanError(f"preload distance must be >= 1, got {d}")
    if d > cfg.fifo_depth:
        raise PlanError(
            f"preload distance {d} exceeds the plan's FIFO depth "
            f"{cfg.fifo_depth}: the warm-up window can never be in flight")
    if engine_fifo_depth is not None and d > engine_fifo_depth:
        raise PlanError(
            f"preload distance {d} exceeds the executing engine's FIFO "
            f"depth {engine_fifo_depth}")
    if cfg.unload_distance < 0:
        raise PlanError(
            f"unload distance must be >= 0, got {cfg.unload_distance}")
    if cfg.num_slots < min(d, max(n_blocks, 1)):
        raise PlanError(
            f"{cfg.num_slots} scratchpad slots cannot hold the {d}-deep "
            "preload window: an in-flight block would overwrite a block "
            "still awaiting its compute")
    if any(s <= 0 for s in cfg.block_shape):
        raise PlanError(f"block_shape must be positive, got {cfg.block_shape}")

    sched = _schedule(cfg, n_blocks)
    issued = set()
    consumed = set()
    in_flight = 0
    max_in_flight = 0
    for op, i in sched:
        if op == "issue":
            if i in issued:
                raise PlanError(f"block {i} preloaded twice")
            issued.add(i)
            in_flight += 1
            max_in_flight = max(max_in_flight, in_flight)
        else:
            if i not in issued:
                raise PlanError(
                    f"block {i} consumed with no preceding preload: the "
                    "compute would read unfetched data")
            if i in consumed:
                raise PlanError(f"block {i} consumed twice")
            consumed.add(i)
            in_flight -= 1
    missing = set(range(n_blocks)) - consumed
    if missing:
        raise PlanError(
            f"schedule does not cover the block set: blocks "
            f"{sorted(missing)[:8]}{'...' if len(missing) > 8 else ''} "
            "are never consumed")
    if issued - set(range(n_blocks)):
        raise PlanError("schedule preloads blocks outside [0, n_blocks)")

    warnings = []
    fifo = min(cfg.fifo_depth, engine_fifo_depth
               if engine_fifo_depth is not None else cfg.fifo_depth)
    if max_in_flight > fifo:
        warnings.append(
            f"in-flight preload window peaks at {max_in_flight} > FIFO "
            f"depth {fifo}: enqueue will back-pressure the PE (modeled as "
            "a stall, legal but slow)")
    if max_in_flight > cfg.num_slots:
        raise PlanError(
            f"in-flight window {max_in_flight} exceeds the {cfg.num_slots} "
            "scratchpad slots: a preload would land on live data")
    return PlanReport(distance=d, n_blocks=n_blocks,
                      max_in_flight=max_in_flight,
                      warnings=tuple(warnings))


def diff_fifo_occupancy(cfg: PULConfig, *, n_blocks: int, channel,
                        engine_fifo_depth: Optional[int] = None) -> List[str]:
    """Diff a PRELOAD channel's *executed* FIFO occupancy against the
    symbolic schedule (the ROADMAP "trace the DMA twin itself" item).

    `channel` is a ``core.dma._Channel`` after an interleaved
    ``run_stream`` (its ``occupancy_log`` samples (model_time, outstanding)
    at every enqueue; ``max_outstanding``/``high_water_time`` carry the
    occupancy high-water tick; ``stalls`` the back-pressure intervals).
    The symbolic side is the same :func:`_schedule` the static verifier
    replays. Returns divergence strings (empty list = the executed
    schedule matches the model):

      * enqueue counts must match the schedule's issue ops 1:1;
      * at the k-th enqueue, executed occupancy (enqueued-not-completed)
        can never exceed the symbolic in-flight window (issued-not-
        consumed) clamped to the FIFO depth — consume waits on the
        completion register, so a deeper executed queue means the engine
        consumed a block whose preload never retired;
      * the occupancy high-water must stay within the symbolic peak;
      * back-pressure must appear in the execution exactly when the static
        verifier modeled it (window > FIFO depth <=> a stalled enqueue).

    Early completions legally make the executed occupancy *shallower* than
    the window (the wire can finish a transfer before its block's turn);
    only exceeding the model is a divergence.
    """
    sched = _schedule(cfg, n_blocks)
    bounds: List[int] = []              # symbolic window after each issue
    in_flight = 0
    peak = 0
    for op, _ in sched:
        if op == "issue":
            in_flight += 1
            peak = max(peak, in_flight)
            bounds.append(in_flight)
        else:
            in_flight -= 1
    fifo = min(cfg.fifo_depth, engine_fifo_depth
               if engine_fifo_depth is not None else cfg.fifo_depth)
    divs: List[str] = []
    log = list(channel.occupancy_log)
    if len(log) != len(bounds):
        divs.append(
            f"executed {len(log)} enqueues but the symbolic schedule "
            f"issues {len(bounds)} preloads")
    for k, ((t, occ), bound) in enumerate(zip(log, bounds)):
        cap = min(bound, fifo)
        if occ > cap:
            divs.append(
                f"enqueue #{k} (model t={t:.3e}): executed occupancy {occ} "
                f"exceeds the symbolic in-flight window {cap}")
    symbolic_peak = min(peak, fifo)
    if channel.max_outstanding > symbolic_peak:
        divs.append(
            f"occupancy high-water {channel.max_outstanding} at model "
            f"t={channel.high_water_time:.3e} exceeds the symbolic peak "
            f"{symbolic_peak}")
    modeled_bp = peak > fifo
    executed_bp = bool(channel.stalls)
    if modeled_bp and not executed_bp:
        divs.append(
            f"verifier modeled back-pressure (window {peak} > FIFO {fifo}) "
            "but no enqueue ever stalled in the execution")
    if executed_bp and not modeled_bp:
        divs.append(
            f"{len(channel.stalls)} enqueue(s) hit FIFO back-pressure but "
            f"the symbolic window ({peak}) never exceeds the FIFO depth "
            f"({fifo})")
    return divs


def verify_kv_page_plan(plan, *, n_pages: int, page_bytes: int,
                        engine_fifo_depth: Optional[int] = None) -> PlanReport:
    """Validate a ``core.planner.Plan`` for a KV-page restore stream.

    Beyond the stream checks, a page plan must be self-consistent: the
    predicted per-block time can never undercut the roofline legs it was
    derived from.
    """
    cfg = plan.cfg
    report = verify_stream_plan(cfg, n_blocks=n_pages,
                                block_bytes=page_bytes,
                                engine_fifo_depth=engine_fifo_depth)
    eps = 1e-12
    if plan.t_compute_per_block < 0 or plan.t_io_per_block < 0:
        raise PlanError("plan carries negative per-block times")
    if plan.predicted_time_per_block + eps < plan.t_compute_per_block:
        raise PlanError(
            "plan predicts a per-block time below its own compute time: "
            f"{plan.predicted_time_per_block} < {plan.t_compute_per_block}")
    return report

"""Backbone assembly: pattern-scanned heterogeneous layer stacks.

A config's ``pattern`` (e.g. gemma3's 5x local + 1 global, zamba2's
(mamba, mamba, shared_attn)) forms one *group*; ``num_groups`` groups are
``lax.scan``-ed with stacked parameters, keeping HLO size O(1) in depth and
enabling clean FSDP all-gather scheduling. Shared blocks (zamba2) live
outside the scan and are closed over; their per-invocation LoRA deltas are
scanned. DeepSeek's dense layer 0 is unscanned.

Three entry points per model: ``loss`` (train), ``prefill`` (process prompt,
emit caches), ``decode_step`` (one token, incremental state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.module import Param, stack_params
from repro.runtime.sharding import constrain


# --------------------------------------------------------------------------
# parameter trees
# --------------------------------------------------------------------------
def _attn_mlp_block_params(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    p: Dict[str, Any] = {"norm1": L.rms_norm_params(cfg.d_model),
                         "norm2": L.rms_norm_params(cfg.d_model)}
    if cfg.sandwich_norm:
        p["post_norm1"] = L.rms_norm_params(cfg.d_model)
        p["post_norm2"] = L.rms_norm_params(cfg.d_model)
    p["attn"] = (MLA.mla_params(cfg) if cfg.attn_type == "mla"
                 else L.attention_params(cfg))
    if kind == "moe":
        p["mlp"] = MOE.moe_params(cfg)
    else:
        p["mlp"] = L.mlp_params(cfg)
    return p


def _lora_params(cfg: ModelConfig) -> Dict[str, Any]:
    D, H, hd, r = cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.shared_lora_rank
    K = cfg.num_kv_heads
    dt = jnp.bfloat16
    return {
        "q_a": Param((D, r), ("embed", "lora"), dt, "fan_in"),
        "q_b": Param((r, H * hd), ("lora", "dinner"), dt, "zeros"),
        "k_a": Param((D, r), ("embed", "lora"), dt, "fan_in"),
        "k_b": Param((r, K * hd), ("lora", "dinner"), dt, "zeros"),
        "v_a": Param((D, r), ("embed", "lora"), dt, "fan_in"),
        "v_b": Param((r, K * hd), ("lora", "dinner"), dt, "zeros"),
    }


def block_params(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    if kind in ("global", "local", "moe", "dense"):
        return _attn_mlp_block_params(cfg, kind)
    if kind == "rwkv":
        return SSM.rwkv_params(cfg)
    if kind == "mamba":
        return SSM.mamba_params(cfg)
    if kind == "shared_attn":
        return _lora_params(cfg) if cfg.shared_lora_rank else {}
    raise ValueError(f"unknown block kind {kind}")


def model_params(cfg: ModelConfig) -> Dict[str, Any]:
    group = {f"{i}:{kind}": block_params(cfg, kind)
             for i, kind in enumerate(cfg.pattern)}
    p: Dict[str, Any] = {
        "embedding": L.embedding_params(cfg),
        "final_norm": L.rms_norm_params(cfg.d_model),
        "groups": stack_params(group, cfg.num_groups, "layers"),
    }
    if cfg.first_dense_layers:
        p["dense"] = {str(i): _attn_mlp_block_params(cfg, "dense")
                      for i in range(cfg.first_dense_layers)}
    if "shared_attn" in cfg.pattern:
        p["shared"] = _attn_mlp_block_params(cfg, "global")
    return p


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------
def _block_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    if kind in ("global", "moe", "dense"):
        if cfg.attn_type == "mla":
            return MLA.mla_cache_spec(cfg, batch, max_seq)
        return L.attention_cache_spec(cfg, batch, max_seq)
    if kind == "local":
        if cfg.paged_kv:            # dense token-indexed layout (pageable)
            return L.attention_cache_spec(cfg, batch, max_seq)
        window = min(cfg.sliding_window or max_seq, max_seq)
        return L.attention_cache_spec(cfg, batch, window)
    if kind == "shared_attn":
        return L.attention_cache_spec(cfg, batch, max_seq)
    if kind == "rwkv":
        return SSM.rwkv_state_spec(cfg, batch)
    if kind == "mamba":
        return SSM.mamba_state_spec(cfg, batch)
    raise ValueError(kind)


def _block_cache_logical(cfg: ModelConfig, kind: str):
    if kind in ("global", "moe", "dense", "local", "shared_attn"):
        if cfg.attn_type == "mla":
            return MLA.mla_cache_logical()
        return L.attention_cache_logical()
    if kind == "rwkv":
        return SSM.rwkv_state_logical()
    if kind == "mamba":
        return SSM.mamba_state_logical()
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    """Abstract decode-cache tree + parallel logical-axes tree."""
    group_spec = {f"{i}:{kind}": _block_cache_spec(cfg, kind, batch, max_seq)
                  for i, kind in enumerate(cfg.pattern)}
    group_logical = {f"{i}:{kind}": _block_cache_logical(cfg, kind)
                     for i, kind in enumerate(cfg.pattern)}
    # stack over scanned groups
    spec = {"groups": jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_groups, *s.shape), s.dtype),
        group_spec)}
    logical = {"groups": jax.tree.map(
        lambda l: (None, *l), group_logical,
        is_leaf=lambda x: isinstance(x, tuple))}
    if cfg.first_dense_layers:
        spec["dense"] = {str(i): _block_cache_spec(cfg, "dense", batch, max_seq)
                         for i in range(cfg.first_dense_layers)}
        logical["dense"] = {str(i): _block_cache_logical(cfg, "dense")
                            for i in range(cfg.first_dense_layers)}
    return spec, logical


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def _merged_lora_attn(shared_attn, lora, cfg: ModelConfig):
    """Zamba2: shared attention weights + per-invocation LoRA deltas."""
    if not lora:
        return shared_attn
    H, K, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    p = dict(shared_attn)
    p["wq"] = shared_attn["wq"] + (lora["q_a"] @ lora["q_b"]).reshape(D, H, hd)
    p["wk"] = shared_attn["wk"] + (lora["k_a"] @ lora["k_b"]).reshape(D, K, hd)
    p["wv"] = shared_attn["wv"] + (lora["v_a"] @ lora["v_b"]).reshape(D, K, hd)
    return p


def block_apply(p, shared, x, *, cfg: ModelConfig, kind: str, positions,
                step_kind: str, cache=None, max_seq=None, paged=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    # recurrent blocks have no pageable KV: a paged decode step is an
    # ordinary decode step for them (state rides in the resident tree)
    ssm_kind = "decode" if step_kind == "paged_decode" else step_kind
    if kind == "rwkv":
        x, new_cache = SSM.rwkv_block_apply(p, x, cfg=cfg, kind=ssm_kind,
                                            state=cache)
        return x, new_cache, aux
    if kind == "mamba":
        x, new_cache = SSM.mamba_block_apply(p, x, cfg=cfg, kind=ssm_kind,
                                             state=cache)
        return x, new_cache, aux

    if kind == "shared_attn":
        blk = shared
        attn_p = _merged_lora_attn(shared["attn"], p, cfg)
    else:
        blk = p
        attn_p = p["attn"]

    h = L.rms_norm(x, blk["norm1"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        h, new_cache = MLA.mla_apply(attn_p, h, cfg=cfg, positions=positions,
                                     kind=step_kind, cache=cache,
                                     max_seq=max_seq, paged=paged)
    else:
        h, new_cache = L.attention_apply(
            attn_p, h, cfg=cfg, positions=positions, kind=step_kind,
            local=(kind == "local"), cache=cache, max_seq=max_seq,
            paged=paged)
    if cfg.sandwich_norm:
        h = L.rms_norm(h, blk["post_norm1"], cfg.norm_eps)
    x = x + h

    h = L.rms_norm(x, blk["norm2"], cfg.norm_eps)
    if kind == "moe":
        h, aux = MOE.moe_apply(blk["mlp"], h, cfg=cfg)
    else:
        h = L.mlp_apply(blk["mlp"], h, cfg=cfg)
    if cfg.sandwich_norm:
        h = L.rms_norm(h, blk["post_norm2"], cfg.norm_eps)
    x = x + h
    return x, new_cache, aux


# --------------------------------------------------------------------------
# backbone
# --------------------------------------------------------------------------
def _embed_inputs(params, batch, cfg: ModelConfig):
    x = L.embed_apply(params["embedding"], batch["tokens"], cfg=cfg)
    if cfg.frontend is not None and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    B, S, _ = x.shape
    pos0 = batch.get("pos0", None)
    base = jnp.arange(S, dtype=jnp.int32)[None, :]
    positions = base if pos0 is None else base + pos0[:, None]
    if cfg.pos_embedding == "sinusoidal":
        x = x + L.sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)
    return x, positions


def backbone(params, x, positions, *, cfg: ModelConfig, step_kind: str,
             caches=None, max_seq=None, paged=None):
    """Runs dense prefix + scanned groups. Returns (x, new_caches, aux)."""
    aux_total = jnp.float32(0.0)
    # single-sweep paged decode: `paged` is a PagedSweep carrying the full
    # per-layer page planes; the backbone sets its (prefix, layer) routing
    # per block and threads the grouped planes through the scan carry
    sweep = paged if isinstance(paged, L.PagedSweep) else None
    new_dense = {}
    if cfg.first_dense_layers:
        for i in range(cfg.first_dense_layers):
            c = None if caches is None else caches["dense"][str(i)]
            if sweep is not None:
                sweep.prefix = ("dense", str(i))
                sweep.layer = 0         # dense planes have layer extent 1
            x, nc, aux = block_apply(params["dense"][str(i)], None, x, cfg=cfg,
                                     kind="dense", positions=positions,
                                     step_kind=step_kind, cache=c,
                                     max_seq=max_seq, paged=paged)
            new_dense[str(i)] = nc
            aux_total += aux

    shared = params.get("shared")

    def group_body(carry, inp):
        x, aux_acc = carry
        gp, gc = inp
        if step_kind == "train":
            # Name the group-boundary activation so the remat policy saves
            # EXACTLY this bf16 tensor per group (and nothing else). With
            # cfg.seq_shard_carry the scan CARRY (which partial_eval saves
            # per group) is sequence-sharded over the model axis — 16x
            # smaller residual stack; the body re-gathers it for compute
            # (§Perf B memory-term move for the giant MoE trainers).
            from jax.ad_checkpoint import checkpoint_name
            x = checkpoint_name(x, "group_carry")
            if cfg.seq_shard_carry:
                x = constrain(x, ("batch", None, None))   # gather to compute
        new_gc = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"{i}:{kind}"
            c = None if gc is None else gc[key]
            if sweep is not None:
                sweep.prefix = ("groups", key)
            x, nc, aux = block_apply(gp[key], shared, x, cfg=cfg, kind=kind,
                                     positions=positions,
                                     step_kind=step_kind, cache=c,
                                     max_seq=max_seq, paged=paged)
            new_gc[key] = nc
            aux_acc = aux_acc + aux
        if step_kind == "train" and cfg.seq_shard_carry:
            x = constrain(x, ("batch", "seq_model", None))  # sharded carry
        else:
            x = constrain(x, ("batch", None, None))
        return (x, aux_acc), new_gc

    body = group_body
    if cfg.remat and step_kind == "train":
        body = jax.checkpoint(
            group_body, prevent_cse=False,
            policy=jax.checkpoint_policies.save_only_these_names("group_carry"))

    if step_kind == "train":
        (x, aux_total), _ = jax.lax.scan(
            lambda c, gp: (body(c, (gp, None))[0], None),
            (x, aux_total), params["groups"])
        new_caches = None
    elif step_kind == "prefill":
        (x, aux_total), new_gcaches = jax.lax.scan(
            lambda c, gp: body(c, (gp, None)),
            (x, aux_total), params["groups"])
        new_caches = {"groups": new_gcaches}
        if cfg.first_dense_layers:
            new_caches["dense"] = new_dense
    else:  # decode
        gkeys = (sorted(k for k in sweep.planes if k.startswith("groups/"))
                 if sweep is not None else [])
        if gkeys:
            # thread the grouped planes through the scan carry: iteration g
            # receives the planes as written by layers < g, the sweep kernel
            # updates row g in place (aliased outputs), and the final carry
            # is the fully committed store
            def sweep_body(carry, inp):
                inner, gplanes = carry
                gp, gc, g = inp
                sweep.layer = g
                for pk in gkeys:
                    sweep.planes[pk] = gplanes[pk]
                inner, new_gc = body(inner, (gp, gc))
                return (inner, {pk: sweep.planes[pk] for pk in gkeys}), new_gc
            ((x, aux_total), gout), new_gcaches = jax.lax.scan(
                sweep_body,
                ((x, aux_total), {pk: sweep.planes[pk] for pk in gkeys}),
                (params["groups"], caches["groups"],
                 jnp.arange(cfg.num_groups, dtype=jnp.int32)))
            for pk in gkeys:
                sweep.planes[pk] = gout[pk]
        else:
            (x, aux_total), new_gcaches = jax.lax.scan(
                body, (x, aux_total), (params["groups"], caches["groups"]))
        new_caches = {"groups": new_gcaches}
        if cfg.first_dense_layers:
            new_caches["dense"] = new_dense
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_caches, aux_total


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------
AUX_COEF = 0.01


def loss_fn(params, batch, *, cfg: ModelConfig):
    """batch: tokens (B,S), targets (B,S), loss_mask (B,S)
    [+ frontend_embeds (B,Tf,D)]. Returns scalar mean NLL (+ MoE aux)."""
    x, positions = _embed_inputs(params, batch, cfg)
    x, _, aux = backbone(params, x, positions, cfg=cfg, step_kind="train")
    if cfg.frontend is not None and "frontend_embeds" in batch:
        x = x[:, batch["frontend_embeds"].shape[1]:, :]
    nll = L.chunked_xent(params["embedding"], x, batch["targets"],
                         batch["loss_mask"], cfg=cfg)
    return nll + AUX_COEF * aux


def prefill_fn(params, batch, *, cfg: ModelConfig, max_seq=None):
    """Returns (last-token logits (B,V), caches). `max_seq` pre-sizes the
    emitted caches for the decode phase (serving engine contract).

    With right-padded prompts the batch may carry per-slot `lengths` (B,);
    logits are then gathered at each row's last REAL token instead of the
    (padded) final position. The emitted cache `idx` leaves still read T —
    a paged engine overwrites them with the true per-slot lengths."""
    x, positions = _embed_inputs(params, batch, cfg)
    x, caches, _ = backbone(params, x, positions, cfg=cfg, step_kind="prefill",
                            max_seq=max_seq)
    if "lengths" in batch:
        last = jnp.clip(batch["lengths"].astype(jnp.int32) - 1, 0,
                        x.shape[1] - 1)
        xl = x[jnp.arange(x.shape[0]), last][:, None, :]
    else:
        xl = x[:, -1:, :]
    logits = L.logits_apply(params["embedding"], xl, cfg=cfg)
    return logits[:, 0, :], caches


def decode_fn(params, batch, caches, *, cfg: ModelConfig):
    """batch: tokens (B,1), pos0 (B,) absolute position of the new token.
    Returns (logits (B,V), new caches)."""
    x, positions = _embed_inputs(params, batch, cfg)
    x, new_caches, _ = backbone(params, x, positions, cfg=cfg,
                                step_kind="decode", caches=caches)
    logits = L.logits_apply(params["embedding"], x, cfg=cfg)
    return logits[:, 0, :], new_caches


def paged_decode_fn(params, batch, caches, planes=None, *, cfg: ModelConfig,
                    pul_distance: int = 4):
    """Kernel-true paged decode step: attention reads KV pages directly.

    batch: tokens (B,1), pos0 (B,) absolute position of the new token,
    page_table (B, n_pages) int32 physical frame of each slot's logical
    page.

    **Single-sweep mode** (`planes` is the `KVStoreLayout` plane dict):
    one launch sequence walks all layers inside the decode scan over the
    FULL per-layer planes — each layer's pages are read by the sweep kernel
    at an SMEM layer scalar (zero-copy: no per-layer gather/slice is built
    under jit) and the current token's K/V rows are committed by the
    kernel's fused epilogue at batch["frames"]/batch["offsets"]. `caches`
    carries only non-pageable state (SSM leaves, idx; pageable leaves may
    be placeholders — only their tree position is used). Returns
    (logits (B,V), new_caches, new_planes).

    **Legacy per-layer mode** (`planes` is None): `caches` is the decode
    tree with every pageable leaf replaced by a physical page view and idx
    leaves set to per-slot fill levels; returns (logits, new_caches) where
    pageable leaves hold ONLY the current token's rows for the engine to
    scatter into each slot's tail page (`KVPagePool.write_rows`).

    `pul_distance` is the preload distance of the in-kernel page ring
    (static; the engine passes the planner's d*)."""
    from repro.core import PULConfig
    x, positions = _embed_inputs(params, batch, cfg)
    pul_cfg = PULConfig(distance=pul_distance)
    page_table = batch["page_table"].astype(jnp.int32)
    if planes is not None:
        paged = L.PagedSweep(
            page_table, pul_cfg,
            jnp.asarray(batch["frames"], jnp.int32),
            jnp.asarray(batch["offsets"], jnp.int32), dict(planes))
    else:
        paged = (page_table, pul_cfg)
    x, new_caches, _ = backbone(params, x, positions, cfg=cfg,
                                step_kind="paged_decode", caches=caches,
                                paged=paged)
    logits = L.logits_apply(params["embedding"], x, cfg=cfg)
    if planes is not None:
        return logits[:, 0, :], new_caches, paged.planes
    return logits[:, 0, :], new_caches

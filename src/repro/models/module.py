"""Minimal parameter-tree module system.

Models are *pure functions* over pytrees of arrays. A model definition builds
an **abstract tree** of :class:`Param` leaves (shape + dtype + logical axis
names + initializer); the helpers here turn that tree into

  * real arrays (`init_tree`, for training / smoke tests),
  * `jax.ShapeDtypeStruct`s (`abstract_tree`, for the AOT dry-run — no
    allocation ever happens for the full-size configs),
  * `PartitionSpec`s / `NamedSharding`s (via `runtime.sharding`).

No flax/haiku dependency: the whole framework stays inspectable pytrees.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.sharding import ShardingRules, logical_to_spec
from jax.sharding import Mesh, NamedSharding


@dataclasses.dataclass(frozen=True)
class Param:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones | fan_in | embed
    scale: Optional[float] = None  # stddev override

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(f"shape {self.shape} vs logical {self.logical}")

    @property
    def nbytes(self) -> int:
        return math.prod(self.shape) * jnp.dtype(self.dtype).itemsize

    @property
    def size(self) -> int:
        return math.prod(self.shape)


def is_param(x) -> bool:
    return isinstance(x, Param)


def _init_leaf(key, p: Param):
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "embed":
        std = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(p.dtype)
    if p.init == "fan_in":
        # fan-in = product of all dims not marked as an output-ish axis; for
        # 2D+ kernels we take the first logical group ("embed"/"ff"/...) as in
        fan = p.shape[0] if len(p.shape) == 1 else math.prod(p.shape[:-1])
        # kernels stored (in..., out) conventionally; attention kernels are
        # (embed, heads, head_dim) -> fan = embed
        if "embed" in (p.logical[0],):
            fan = p.shape[0]
        std = p.scale if p.scale is not None else 1.0 / math.sqrt(max(fan, 1))
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(p.dtype)
    if p.init == "normal":
        std = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(p.dtype)
    raise ValueError(f"unknown init {p.init}")


def init_tree(key, tree):
    """Materialize a Param tree into arrays (host/device)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_param)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, p) for k, p in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(tree):
    """Param tree -> ShapeDtypeStruct tree (no allocation; dry-run input)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), tree, is_leaf=is_param
    )


def logical_tree(tree):
    return jax.tree.map(lambda p: p.logical, tree, is_leaf=is_param)


def param_specs(tree, mesh: Mesh, rules: ShardingRules = ShardingRules()):
    """Param tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda p: logical_to_spec(p.logical, p.shape, mesh, rules),
        tree,
        is_leaf=is_param,
    )


def param_shardings(tree, mesh: Mesh, rules: ShardingRules = ShardingRules()):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, logical_to_spec(p.logical, p.shape, mesh, rules)),
        tree,
        is_leaf=is_param,
    )


def count_params(tree) -> int:
    return sum(p.size for p in jax.tree.leaves(tree, is_leaf=is_param))


def count_bytes(tree) -> int:
    return sum(p.nbytes for p in jax.tree.leaves(tree, is_leaf=is_param))


def stack_params(tree, n: int, axis_name: Optional[str] = None):
    """Add a leading 'layers' axis to every Param (for lax.scan over groups)."""
    return jax.tree.map(
        lambda p: Param((n, *p.shape), (axis_name, *p.logical), p.dtype, p.init, p.scale),
        tree,
        is_leaf=is_param,
    )

"""Shared transformer layers: norms, RoPE, GQA attention, MLPs, embeddings.

All layers are (param-tree builder, pure apply fn) pairs. Attention supports
the zoo's flavors: GQA grouping, per-head qk RMS-norm (qwen3/gemma3), QKV
bias (qwen2.5), attention-logit softcap (gemma2/grok), sliding-window local
layers (gemma2/3), and an incremental KV-cache decode path.

Compute dtype is bf16 with fp32 softmax/norm internals.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.module import Param
from repro.runtime.sharding import constrain as _constrain

NEG_INF = -2.0e38  # large finite; avoids nan from (-inf) - (-inf)


class PagedSweep:
    """Routing context for the single-sweep paged decode.

    When the engine serves from per-layer page planes (`KVStoreLayout` v2),
    `paged` carries one of these instead of the legacy (page_table, PULConfig)
    tuple. The backbone threads the FULL stacked planes through the layer
    scan's carry and mutates this context as tracing walks the blocks: it
    sets `prefix` to the current block's cache path and `layer` to the
    scan-carried group index; each attention block then reads its planes via
    :meth:`plane`, calls the sweep kernel (which selects the layer in-kernel
    from an SMEM scalar and commits the current token's rows in its fused
    epilogue), and writes the aliased plane outputs back via
    :meth:`set_plane`. `frames`/`offsets` ((B,) int32) name each slot's
    tail-page commit position (TRASH frame for inactive slots).
    """

    def __init__(self, page_table, pul_cfg, frames, offsets, planes):
        self.page_table = page_table
        self.pul_cfg = pul_cfg
        self.frames = frames
        self.offsets = offsets
        self.planes = planes        # {plane_key: (L, NF, ...) full plane}
        self.prefix: Tuple[str, ...] = ()
        self.layer = 0              # traced group index inside the scan

    def _key(self, leaf: str) -> str:
        return "/".join((*self.prefix, leaf))

    def plane(self, leaf: str):
        return self.planes[self._key(leaf)]

    def set_plane(self, leaf: str, value) -> None:
        self.planes[self._key(leaf)] = value


# --------------------------------------------------------------------------
# norms / positions
# --------------------------------------------------------------------------
def rms_norm_params(dim: int, name_axis: str = "norm") -> Param:
    return Param((dim,), (name_axis,), dtype=jnp.float32, init="zeros")


def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm with gemma-style (1 + scale) parameterization (zero init)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x, positions, theta: float):
    """Half-split rotary embedding. x: (..., seq, n, head_dim), positions
    broadcastable to (..., seq)."""
    dt = x.dtype
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None, None] * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half : 2 * half].astype(jnp.float32)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if hd != 2 * half:  # odd head_dim (zamba 112 is even; guard anyway)
        rot = jnp.concatenate([rot, x[..., 2 * half :].astype(jnp.float32)], axis=-1)
    return rot.astype(dt)


def sinusoidal_embedding(positions, dim: int, max_scale: float = 1e4):
    """Absolute sinusoidal position embedding (musicgen). positions: (B,S)."""
    half = dim // 2
    freqs = max_scale ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def attention_params(cfg: ModelConfig) -> Dict[str, Any]:
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.bfloat16
    p: Dict[str, Any] = {
        "wq": Param((D, H, hd), ("embed", "heads", "head_dim"), dt, "fan_in"),
        "wk": Param((D, K, hd), ("embed", "kv_heads", "head_dim"), dt, "fan_in"),
        "wv": Param((D, K, hd), ("embed", "kv_heads", "head_dim"), dt, "fan_in"),
        "wo": Param((H, hd, D), ("heads", "head_dim", "embed"), dt, "fan_in"),
    }
    if cfg.qkv_bias:
        p["bq"] = Param((H, hd), ("heads", "head_dim"), dt, "zeros")
        p["bk"] = Param((K, hd), ("kv_heads", "head_dim"), dt, "zeros")
        p["bv"] = Param((K, hd), ("kv_heads", "head_dim"), dt, "zeros")
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_params(hd)
        p["k_norm"] = rms_norm_params(hd)
    return p


def _attend(q, k, v, *, mask, softcap: Optional[float], scale: float):
    """q: (B,T,H,hd) k/v: (B,S,K,hd); grouped-query attention core."""
    B, T, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    q = q.reshape(B, T, K, G, hd)
    logits = jnp.einsum("btkgh,bskh->bkgts", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v)
    return out.reshape(B, T, H, hd)


# KV-block length for the streaming (flash-style) attention path. Sequences
# longer than 2*KV_BLOCK never materialize (T, S) scores — the XLA-level
# analogue of kernels/pul_attention.py (which is the TPU-optimal realization
# of the same schedule: preload KV tiles, online softmax, unload out-tiles).
KV_BLOCK = 1024


def _attend_chunked(q, k, v, *, softcap: Optional[float], scale: float,
                    window: Optional[int], kv_block: int = KV_BLOCK):
    """Causal GQA attention, lax.scan over KV blocks with online softmax.

    Math-identical to `_attend` with a causal (+optional sliding window)
    mask; peak memory is O(T * kv_block) per head instead of O(T * S)."""
    B, T, H, hd = q.shape
    S = k.shape[1]
    K = k.shape[2]
    vd = v.shape[-1]                                           # may differ (MLA)
    G = H // K
    nb = -(-S // kv_block)
    pad = nb * kv_block - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = q.reshape(B, T, K, G, hd)
    kb = k.reshape(B, nb, kv_block, K, hd).swapaxes(0, 1)     # (nb,B,kb,K,hd)
    vb = v.reshape(B, nb, kv_block, K, vd).swapaxes(0, 1)
    offs = jnp.arange(nb, dtype=jnp.int32) * kv_block
    iq = jnp.arange(T)                                         # absolute = iq (T==S)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, off = inp
        logits = jnp.einsum("btkgh,bskh->bkgts", qg, kc).astype(jnp.float32) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        jk = off + jnp.arange(kv_block)
        msk = (jk[None, :] <= iq[:, None]) & (jk[None, :] < S)
        if window is not None:
            msk &= jk[None, :] > iq[:, None] - window
        logits = jnp.where(msk[None, None, None], logits, NEG_INF)
        bmax = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m, bmax)
        corr = jnp.exp(m - new_m)
        p = jnp.exp(logits - new_m[..., None])
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgts,bskh->bkgth", p, vc)
        return (new_m, l, acc), ()

    m0 = jnp.full((B, K, G, T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, T), jnp.float32)
    a0 = jnp.zeros((B, K, G, T, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, offs))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.swapaxes(2, 3).swapaxes(1, 2).reshape(B, T, H, vd).astype(v.dtype)


def _causal_mask(tq: int, tk: int, *, offset: int, window: Optional[int]):
    """(1,1,1,tq,tk) boolean mask. `offset` = absolute position of query 0
    minus absolute position of key 0 (decode: cache_len-1)."""
    iq = jnp.arange(tq)[:, None] + offset
    jk = jnp.arange(tk)[None, :]
    m = jk <= iq
    if window is not None:
        m &= jk > iq - window
    return m[None, None, None]


def attention_apply(
    p,
    x,
    *,
    cfg: ModelConfig,
    positions,
    kind: str,              # "train" | "prefill" | "decode" | "paged_decode"
    local: bool = False,
    cache: Optional[Dict[str, Any]] = None,
    max_seq: Optional[int] = None,  # prefill: emit caches sized for decode
    paged: Optional[Tuple] = None,  # paged_decode: (page_table, PULConfig)
):
    """Returns (y, new_cache). Cache: {"k","v": (B,Smax,K,hd), "idx": ()}.

    kind="paged_decode" consumes a PAGED cache instead: {"k","v":
    (NP, K, P, hd) physical page frames, "idx": (B,) per-slot fill}, with the
    logical->physical map in `paged` — attention streams straight over the
    pages (kernels.pul_paged_decode_attention) and the returned cache holds
    only the current token's rows for the engine to scatter into its page."""
    B, T, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _constrain(q, ("batch", None, "act_heads", None))
    k = _constrain(k, ("batch", None, "act_kv_heads", None))
    v = _constrain(v, ("batch", None, "act_kv_heads", None))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    theta = cfg.rope_theta
    if local and cfg.local_rope_theta is not None:
        theta = cfg.local_rope_theta
    if cfg.pos_embedding == "rope":
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    scale = 1.0 / math.sqrt(hd)
    window = cfg.sliding_window if local else None

    if kind == "paged_decode":
        # Kernel-true paged decode: no dense (B, S) view is ever assembled —
        # the PUL preload ring pulls physical pages in page-table order and
        # the current token's K/V (not yet in any page) merges in after the
        # stream. Sliding windows are an in-kernel mask term (paged layouts
        # are token-indexed, never rings).
        assert T == 1, "paged decode processes one token per step"
        assert paged is not None, "paged_decode needs (page_table, PULConfig)"
        idx = jnp.asarray(cache["idx"], jnp.int32).reshape(B)
        if isinstance(paged, PagedSweep):
            # single-sweep path: the kernel reads THIS layer out of the full
            # per-layer planes (SMEM layer scalar) and commits k_new/v_new
            # in its fused epilogue — no host-side view slicing or scatter
            from repro.kernels.pul_attention import (
                pul_paged_sweep_decode_attention)
            kp, vp = paged.plane("k"), paged.plane("v")
            k_new = k[:, 0].astype(kp.dtype)
            v_new = v[:, 0].astype(vp.dtype)
            out, kp, vp = pul_paged_sweep_decode_attention(
                q[:, 0], kp, vp, paged.layer, paged.page_table, idx,
                k_new, v_new, paged.frames, paged.offsets, scale=scale,
                softcap=cfg.attn_softcap, window=window, cfg=paged.pul_cfg)
            paged.set_plane("k", kp)
            paged.set_plane("v", vp)
        else:
            from repro.kernels.pul_attention import pul_paged_decode_attention
            page_table, pul_cfg = paged
            k_new = k[:, 0].astype(cache["k"].dtype)
            v_new = v[:, 0].astype(cache["v"].dtype)
            out = pul_paged_decode_attention(
                q[:, 0], cache["k"], cache["v"], page_table, idx,
                scale=scale, softcap=cfg.attn_softcap, window=window,
                k_new=k_new, v_new=v_new, cfg=pul_cfg)
        out = out[:, None]
        new_cache = {"k": k_new, "v": v_new, "idx": idx + 1}
    elif kind == "decode":
        # Per-slot fill levels: idx is a (B,) vector — each serving slot
        # tracks its own sequence length, which is what lets a continuous-
        # batching engine refill one slot without touching the others.
        # Global layers: cache holds max_seq slots, token t at row t.
        # Local layers, non-paged: cache is a RING of `window` slots (token t
        # lives at slot t % window); overwriting implements the sliding
        # window, so the mask only needs "slot already filled".
        # Local layers, paged_kv: cache is dense token-indexed like global
        # (pages must map 1:1 onto token ranges), so the sliding window is an
        # explicit mask term instead.
        assert T == 1, "decode processes one token per step"
        idx = jnp.broadcast_to(jnp.asarray(cache["idx"], jnp.int32), (B,))
        S = cache["k"].shape[1]
        write = jax.lax.rem(idx, S)                       # (B,)
        rows = jnp.arange(B)
        kc = cache["k"].at[rows, write].set(k[:, 0].astype(cache["k"].dtype))
        vc = cache["v"].at[rows, write].set(v[:, 0].astype(cache["v"].dtype))
        jk = jnp.arange(S)[None, :]
        mask2d = jk <= idx[:, None]                       # (B, S)
        if cfg.paged_kv and window is not None and S > window:
            mask2d &= jk > idx[:, None] - window
        mask = mask2d[:, None, None, None, :]             # (B,1,1,1,S)
        out = _attend(q, kc, vc, mask=mask, softcap=cfg.attn_softcap, scale=scale)
        new_cache = {"k": kc, "v": vc, "idx": idx + 1}
    else:
        if T > 2 * KV_BLOCK:
            out = _attend_chunked(q, k, v, softcap=cfg.attn_softcap,
                                  scale=scale, window=window)
        else:
            mask = _causal_mask(T, T, offset=0, window=window)
            out = _attend(q, k, v, mask=mask, softcap=cfg.attn_softcap,
                          scale=scale)
        new_cache = None
        if kind == "prefill":
            kc, vc = k, v
            target = max_seq or T
            if window is not None and not cfg.paged_kv:
                target = min(window, target)
            if T > target:
                # keep the last `target` tokens, ring-aligned (slot = t % W)
                o = T % target
                kc = jnp.roll(k[:, T - target:], o, axis=1)
                vc = jnp.roll(v[:, T - target:], o, axis=1)
            elif T < target:
                pad = ((0, 0), (0, target - T), (0, 0), (0, 0))
                kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
            new_cache = {"k": kc.astype(jnp.bfloat16), "v": vc.astype(jnp.bfloat16),
                         "idx": jnp.full((B,), T, jnp.int32)}
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return y, new_cache


def attention_cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    """Abstract cache entry for one attention layer (dry-run input_specs)."""
    K, hd = cfg.num_kv_heads, cfg.head_dim
    arr = jax.ShapeDtypeStruct((batch, max_seq, K, hd), jnp.bfloat16)
    return {"k": arr, "v": arr,
            "idx": jax.ShapeDtypeStruct((batch,), jnp.int32)}


def attention_cache_logical():
    kv = ("cache_batch", "cache_seq", "act_kv_heads", "head_dim")
    return {"k": kv, "v": kv, "idx": ("cache_batch",)}


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def mlp_params(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    dt = jnp.bfloat16
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": Param((D, F), ("embed", "ff"), dt, "fan_in"),
            "w_up": Param((D, F), ("embed", "ff"), dt, "fan_in"),
            "w_down": Param((F, D), ("ff", "embed"), dt, "fan_in"),
        }
    if cfg.mlp_type == "gelu":
        return {
            "w_in": Param((D, F), ("embed", "ff"), dt, "fan_in"),
            "w_out": Param((F, D), ("ff", "embed"), dt, "fan_in"),
        }
    raise ValueError(f"mlp_type {cfg.mlp_type} handled elsewhere")


def mlp_apply(p, x, *, cfg: ModelConfig):
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else (
            lambda t: jax.nn.gelu(t, approximate=True))
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
        return h @ p["w_down"]
    if cfg.mlp_type == "gelu":
        return jax.nn.gelu(x @ p["w_in"], approximate=True) @ p["w_out"]
    raise ValueError(cfg.mlp_type)


# --------------------------------------------------------------------------
# embedding + chunked cross-entropy (streamed over vocab tiles — the softmax
# analogue of PUL: the (B,S,V) logits tensor never materializes)
# --------------------------------------------------------------------------
def embedding_params(cfg: ModelConfig) -> Dict[str, Any]:
    V = cfg.padded_vocab
    p = {"table": Param((V, cfg.d_model), ("vocab", "embed"),
                        jnp.bfloat16, "embed")}
    if not cfg.tie_embeddings:
        p["lm_head"] = Param((V, cfg.d_model), ("vocab", "embed"),
                             jnp.bfloat16, "fan_in", scale=0.02)
    return p


def embed_apply(p, tokens, *, cfg: ModelConfig):
    x = jnp.take(p["table"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _head_table(p, cfg: ModelConfig):
    return p["table"] if cfg.tie_embeddings else p["lm_head"]


def logits_apply(p, x, *, cfg: ModelConfig):
    """Full logits — decode path only (T=1), (B,1,V)."""
    w = _head_table(p, cfg)
    logits = jnp.einsum("btd,vd->btv", x, w).astype(jnp.float32)
    logits = _constrain(logits, ("batch", None, "vocab"))
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits[..., : cfg.vocab_size]


def chunked_xent(p, x, targets, mask, *, cfg: ModelConfig):
    """Streaming cross-entropy over vocab tiles.

    Never materializes (B,S,V): scans vocab chunks, maintaining an online
    logsumexp and gathering the target logit on the fly. Each chunk is
    rematerialized in the backward pass (jax.checkpoint).
    x: (B,S,D) final hiddens; targets: (B,S) int32; mask: (B,S) {0,1}.
    Returns mean nll over masked tokens.
    """
    w = _head_table(p, cfg)
    V = cfg.vocab_size                       # true vocab (pads masked below)
    Vp, D = w.shape                          # padded table rows
    C = min(cfg.vocab_chunk, Vp)
    n_chunks = Vp // C
    wp = _constrain(w.reshape(n_chunks, C, D), (None, "vocab", "embed"))

    B, S, _ = x.shape
    neg = jnp.float32(NEG_INF)

    @jax.checkpoint
    def chunk_step(carry, inp):
        m, lse, tgt_logit = carry
        wc, off = inp
        logits = jnp.einsum("bsd,cd->bsc", x, wc).astype(jnp.float32)
        logits = _constrain(logits, ("batch", None, "vocab"))
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        # mask padded vocab rows
        valid = (off + jnp.arange(C)) < V
        logits = jnp.where(valid[None, None, :], logits, neg)
        cmax = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m, cmax)
        lse = jnp.exp(m - new_m) * lse + jnp.sum(
            jnp.exp(logits - new_m[..., None]), axis=-1)
        # gather target logit if it falls in this chunk
        loc = targets - off
        in_chunk = (loc >= 0) & (loc < C)
        gathered = jnp.take_along_axis(
            logits, jnp.clip(loc, 0, C - 1)[..., None], axis=-1)[..., 0]
        tgt_logit = jnp.where(in_chunk, gathered, tgt_logit)
        return (new_m, lse, tgt_logit), ()

    init = (jnp.full((B, S), neg, jnp.float32),
            jnp.zeros((B, S), jnp.float32),
            jnp.full((B, S), neg, jnp.float32))
    offs = jnp.arange(n_chunks, dtype=jnp.int32) * C
    (m, lse, tgt_logit), _ = jax.lax.scan(chunk_step, init, (wp, offs))
    nll = (m + jnp.log(lse)) - tgt_logit
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

from repro.models.zoo import Model, build_model, input_specs, demo_batch
from repro.models import module, layers, transformer, moe, mla, ssm

__all__ = ["Model", "build_model", "input_specs", "demo_batch",
           "module", "layers", "transformer", "moe", "mla", "ssm"]

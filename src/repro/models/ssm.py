"""Attention-free mixers: RWKV6 (Finch) and Mamba2 (SSD).

Both come in two mathematically-identical forms:
  * chunked (train/prefill): scan over chunks of ``cfg.chunk_size`` with
    dense intra-chunk math — every decay exponent in the factorization is
    <= 0, so nothing overflows regardless of learned decay magnitudes;
  * recurrent (decode + test oracle): one step at a time, O(1) state.

RWKV6 recurrence (per head, key-dim N, value-dim N; per-CHANNEL decay):
    out_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T ,   w_t = exp(-exp(w0 + lora(x_t)))
Mamba2 / SSD (per head, scalar decay a_t = exp(A * dt_t)):
    h_t = a_t h_{t-1} + (dt_t x_t) B_t^T ;  y_t = C_t . h_t + D x_t

Faithfulness notes (DESIGN.md §5): RWKV6's data-*dependent* token-shift
(ddlerp) is kept for the decay (its critical use) and static for the r/k/v/g
mixes; group-norm over heads follows the reference.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm, rms_norm_params
from repro.models.module import Param


def _shift(x, x_prev):
    """Token shift: returns x_{t-1} along seq; slot 0 filled from x_prev
    (B,D) carry (zeros at sequence start)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _lerp(x, x_shift, mu):
    return x + (x_shift - x) * mu


# ==========================================================================
# RWKV6
# ==========================================================================
def rwkv_params(cfg: ModelConfig) -> Dict[str, Any]:
    D = cfg.d_model
    H, N = cfg.ssm_heads, cfg.ssm_head_dim
    dt = jnp.bfloat16
    lora = 64
    return {
        "norm_t": rms_norm_params(D),
        "norm_c": rms_norm_params(D),
        # time-mix
        "mu_r": Param((D,), ("embed",), jnp.float32, "normal", 0.2),
        "mu_k": Param((D,), ("embed",), jnp.float32, "normal", 0.2),
        "mu_v": Param((D,), ("embed",), jnp.float32, "normal", 0.2),
        "mu_w": Param((D,), ("embed",), jnp.float32, "normal", 0.2),
        "mu_g": Param((D,), ("embed",), jnp.float32, "normal", 0.2),
        "wr": Param((D, H * N), ("embed", "dinner"), dt, "fan_in"),
        "wk": Param((D, H * N), ("embed", "dinner"), dt, "fan_in"),
        "wv": Param((D, H * N), ("embed", "dinner"), dt, "fan_in"),
        "wg": Param((D, H * N), ("embed", "dinner"), dt, "fan_in"),
        "w0": Param((H, N), ("state", "head_dim"), jnp.float32, "normal", 0.5),
        "wd_a": Param((D, 64), ("embed", "lora"), dt, "fan_in"),
        "wd_b": Param((64, H * N), ("lora", "dinner"), dt, "fan_in"),
        "u": Param((H, N), ("state", "head_dim"), jnp.float32, "normal", 0.5),
        "ln_scale": Param((H, N), ("state", "head_dim"), jnp.float32, "zeros"),
        "ln_bias": Param((H, N), ("state", "head_dim"), jnp.float32, "zeros"),
        "wo": Param((H * N, D), ("dinner", "embed"), dt, "fan_in"),
        # channel-mix
        "cmu_k": Param((D,), ("embed",), jnp.float32, "normal", 0.2),
        "cmu_r": Param((D,), ("embed",), jnp.float32, "normal", 0.2),
        "cw_k": Param((D, cfg.d_ff), ("embed", "ff"), dt, "fan_in"),
        "cw_v": Param((cfg.d_ff, D), ("ff", "embed"), dt, "fan_in"),
        "cw_r": Param((D, D), ("embed", "act_embed"), dt, "fan_in"),
    }


def _rwkv_chunk(r, k, v, logw, u, S0):
    """One chunk, all heads. r,k,v,logw: (B,C,H,N) fp32; u: (H,N);
    S0: (B,H,N,N). Returns (out (B,C,H,N), S_C)."""
    B, C, H, N = r.shape
    L = jnp.cumsum(logw, axis=1)                        # L_t, t=1..C  (<=0)
    L_prev = L - logw                                   # L_{t-1}
    # intra-chunk, strictly causal: decay exp(L_{t-1} - L_j) for j <= t-1
    dec = L_prev[:, :, None] - L[:, None, :]            # (B,C,C,H,N): t,j
    tri = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])[None, :, :, None, None]
    dec = jnp.where(tri, dec, -jnp.inf)                 # mask j >= t
    scores = jnp.einsum("bthn,bjhn,btjhn->bhtj", r, k, jnp.exp(dec))
    diag = jnp.einsum("bthn,hn,bthn->bth", r, u, k)     # u-bonus at j=t
    out = jnp.einsum("bhtj,bjhn->bthn", scores, v)
    out = out + jnp.einsum("bth,bthn->bthn", diag, v)
    # inter-chunk: r_t decayed to chunk start, applied to S0
    out = out + jnp.einsum("bthn,bhnm->bthm", r * jnp.exp(L_prev), S0)
    # state update: S_C = diag(exp(L_C)) S0 + sum_j (k_j exp(L_C - L_j)) v_j
    k_dec = k * jnp.exp(L[:, -1:, :, :] - L)
    S = jnp.exp(L[:, -1])[..., None] * S0 + jnp.einsum("bjhn,bjhm->bhnm", k_dec, v)
    return out, S


def rwkv_wkv_chunked(r, k, v, logw, u, S0, chunk: int):
    """(B,S,H,N) inputs -> (out (B,S,H,N), S_final). Exact chunked scan.

    Non-multiple sequence lengths are padded with identity steps (k=0,
    logw=0 => state untouched) and sliced back."""
    B, S, H, N = r.shape
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = zp(r), zp(k), zp(v), zp(logw)
        out, S_final = rwkv_wkv_chunked(r, k, v, logw, u, S0, chunk)
        return out[:, :S], S_final
    n = S // C

    def to_chunks(t):
        return t.reshape(B, n, C, H, N).swapaxes(0, 1)  # (n,B,C,H,N)

    rs, ks, vs, ws = map(to_chunks, (r, k, v, logw))

    def body(Sc, inp):
        rc, kc, vc, wc = inp
        out, Sc = _rwkv_chunk(rc, kc, vc, wc, u, Sc)
        return Sc, out

    S_final, outs = jax.lax.scan(body, S0, (rs, ks, vs, ws))
    return outs.swapaxes(0, 1).reshape(B, S, H, N), S_final


def rwkv_wkv_recurrent(r, k, v, logw, u, S0):
    """Step-by-step oracle (and decode path when S==1)."""
    def step(S, inp):
        rt, kt, vt, wt = inp                            # (B,H,N)
        out = jnp.einsum("bhn,bhnm->bhm", rt, S) + \
              jnp.einsum("bhn,hn,bhn,bhm->bhm", rt, u, kt, vt)
        S = jnp.exp(wt)[..., None] * S + jnp.einsum("bhn,bhm->bhnm", kt, vt)
        return S, out

    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, logw))   # (S,B,H,N)
    S_final, outs = jax.lax.scan(step, S0, xs)
    return outs.swapaxes(0, 1), S_final


def _rwkv_time_mix(p, x, *, cfg: ModelConfig, state, kind: str):
    B, S, D = x.shape
    H, N = cfg.ssm_heads, cfg.ssm_head_dim
    x_prev = state["x_tm"] if state is not None else jnp.zeros((B, D), x.dtype)
    xs = _shift(x, x_prev)
    f32 = jnp.float32
    xr = _lerp(x, xs, p["mu_r"].astype(x.dtype))
    xk = _lerp(x, xs, p["mu_k"].astype(x.dtype))
    xv = _lerp(x, xs, p["mu_v"].astype(x.dtype))
    xw = _lerp(x, xs, p["mu_w"].astype(x.dtype))
    xg = _lerp(x, xs, p["mu_g"].astype(x.dtype))
    r = (xr @ p["wr"]).reshape(B, S, H, N).astype(f32)
    k = (xk @ p["wk"]).reshape(B, S, H, N).astype(f32)
    v = (xv @ p["wv"]).reshape(B, S, H, N).astype(f32)
    g = jax.nn.silu(xg @ p["wg"]).reshape(B, S, H, N)
    dd = (jnp.tanh(xw @ p["wd_a"]) @ p["wd_b"]).reshape(B, S, H, N).astype(f32)
    logw = -jnp.exp(p["w0"][None, None] + dd)           # < 0
    S0 = state["S"] if state is not None else jnp.zeros((B, H, N, N), f32)
    if kind == "decode":
        out, S_new = rwkv_wkv_recurrent(r, k, v, logw, p["u"], S0)
    else:
        out, S_new = rwkv_wkv_chunked(r, k, v, logw, p["u"], S0, cfg.chunk_size)
    # per-head group norm
    mean = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 64e-5)
    out = out * (1.0 + p["ln_scale"]) + p["ln_bias"]
    y = (out.astype(x.dtype) * g).reshape(B, S, H * N) @ p["wo"]
    new_state = None
    if kind in ("decode", "prefill"):
        new_state = {"S": S_new, "x_tm": x[:, -1, :]}
    return y, new_state


def _rwkv_channel_mix(p, x, *, cfg: ModelConfig, state, kind: str):
    B, S, D = x.shape
    x_prev = state["x_cm"] if state is not None else jnp.zeros((B, D), x.dtype)
    xs = _shift(x, x_prev)
    xk = _lerp(x, xs, p["cmu_k"].astype(x.dtype))
    xr = _lerp(x, xs, p["cmu_r"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(xk @ p["cw_k"]))
    y = jax.nn.sigmoid(xr @ p["cw_r"]) * (kk @ p["cw_v"])
    new_state = {"x_cm": x[:, -1, :]} if kind in ("decode", "prefill") else None
    return y, new_state


def rwkv_block_apply(p, x, *, cfg: ModelConfig, kind: str,
                     state: Optional[Dict[str, Any]] = None):
    """Full RWKV block: time-mix + channel-mix sublayers with own norms."""
    tm_state = None if state is None else {"S": state["S"], "x_tm": state["x_tm"]}
    h, tm_new = _rwkv_time_mix(p, rms_norm(x, p["norm_t"], cfg.norm_eps),
                               cfg=cfg, state=tm_state, kind=kind)
    x = x + h
    cm_state = None if state is None else {"x_cm": state["x_cm"]}
    h, cm_new = _rwkv_channel_mix(p, rms_norm(x, p["norm_c"], cfg.norm_eps),
                                  cfg=cfg, state=cm_state, kind=kind)
    x = x + h
    new_state = None
    if kind in ("decode", "prefill"):
        new_state = {**tm_new, **cm_new}
    return x, new_state


def rwkv_state_spec(cfg: ModelConfig, batch: int):
    H, N, D = cfg.ssm_heads, cfg.ssm_head_dim, cfg.d_model
    return {
        "S": jax.ShapeDtypeStruct((batch, H, N, N), jnp.float32),
        "x_tm": jax.ShapeDtypeStruct((batch, D), jnp.bfloat16),
        "x_cm": jax.ShapeDtypeStruct((batch, D), jnp.bfloat16),
    }


def rwkv_state_logical():
    return {
        "S": ("cache_batch", "act_heads", None, None),
        "x_tm": ("cache_batch", None),
        "x_cm": ("cache_batch", None),
    }


# ==========================================================================
# Mamba2 (SSD)
# ==========================================================================
def mamba_params(cfg: ModelConfig) -> Dict[str, Any]:
    D, din = cfg.d_model, cfg.d_inner
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = din // H
    K = cfg.conv_kernel
    dt = jnp.bfloat16
    conv_ch = din + 2 * N
    return {
        "norm": rms_norm_params(D),
        "in_proj": Param((D, 2 * din + 2 * N + H), ("embed", "dinner"), dt, "fan_in"),
        "conv_w": Param((K, conv_ch), ("conv", "dinner"), dt, "normal", 0.2),
        "conv_b": Param((conv_ch,), ("dinner",), dt, "zeros"),
        "A_log": Param((H,), ("state",), jnp.float32, "normal", 0.5),
        "D_skip": Param((H,), ("state",), jnp.float32, "ones"),
        "dt_bias": Param((H,), ("state",), jnp.float32, "zeros"),
        "gn_scale": Param((din,), ("dinner",), jnp.float32, "zeros"),
        "out_proj": Param((din, D), ("dinner", "embed"), dt, "fan_in"),
    }


def _ssd_chunk(x, B_, C_, la, dt_, S0):
    """x: (B,C,H,P) dt-scaled inputs; B_,C_: (B,C,N); la: (B,C,H) log-decay
    cumsum-able; dt_: (B,C,H); S0: (B,H,P,N). h read AFTER update (j<=t)."""
    Bb, C, H, P = x.shape
    L = jnp.cumsum(la, axis=1)                           # (B,C,H), <=0
    dec = L[:, :, None, :] - L[:, None, :, :]            # (B,t,j,H)
    tri = (jnp.arange(C)[:, None] >= jnp.arange(C)[None, :])[None, :, :, None]
    dec = jnp.where(tri, dec, -jnp.inf)
    cb = jnp.einsum("btn,bjn->btj", C_, B_)              # (B,t,j)
    scores = cb[..., None] * jnp.exp(dec)                # (B,t,j,H)
    out = jnp.einsum("btjh,bjh,bjhp->bthp", scores, dt_, x)
    out = out + jnp.einsum("btn,bth,bhpn->bthp", C_, jnp.exp(L), S0)
    k_dec = dt_ * jnp.exp(L[:, -1:, :] - L)              # (B,j,H)
    S = jnp.exp(L[:, -1])[..., None, None] * S0 + \
        jnp.einsum("bjh,bjhp,bjn->bhpn", k_dec, x, B_)
    return out, S


def mamba_ssd_chunked(x, B_, C_, la, dt_, S0, chunk: int):
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        out, S_final = mamba_ssd_chunked(zp(x), zp(B_), zp(C_), zp(la),
                                         zp(dt_), S0, chunk)
        return out[:, :S], S_final
    n = S // C

    def ck(t, feat):
        return t.reshape(Bb, n, C, *feat).swapaxes(0, 1)

    xs, bs, cs = ck(x, (H, P)), ck(B_, (N,)), ck(C_, (N,))
    las, dts = ck(la, (H,)), ck(dt_, (H,))

    def body(Sc, inp):
        xc, bc, cc, lac, dtc = inp
        out, Sc = _ssd_chunk(xc, bc, cc, lac, dtc, Sc)
        return Sc, out

    S_final, outs = jax.lax.scan(body, S0, (xs, bs, cs, las, dts))
    return outs.swapaxes(0, 1).reshape(Bb, S, H, P), S_final


def mamba_ssd_recurrent(x, B_, C_, la, dt_, S0):
    def step(S, inp):
        xt, bt, ct, lat, dtt = inp
        S = jnp.exp(lat)[..., None, None] * S + \
            jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        out = jnp.einsum("bn,bhpn->bhp", ct, S)
        return S, out

    xs = (x.swapaxes(0, 1), B_.swapaxes(0, 1), C_.swapaxes(0, 1),
          la.swapaxes(0, 1), dt_.swapaxes(0, 1))
    S_final, outs = jax.lax.scan(step, S0, xs)
    return outs.swapaxes(0, 1), S_final


def _depthwise_conv(xbc, w, b, conv_state):
    """Causal depthwise conv1d, kernel K. xbc: (B,S,Ch); w: (K,Ch);
    conv_state: (B,K-1,Ch) trailing context (zeros at start)."""
    K = w.shape[0]
    full = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    out = sum(full[:, i : full.shape[1] - (K - 1 - i), :] * w[i] for i in range(K))
    new_state = full[:, -(K - 1):, :]
    return jax.nn.silu(out + b), new_state


def mamba_block_apply(p, x, *, cfg: ModelConfig, kind: str,
                      state: Optional[Dict[str, Any]] = None):
    B, S, D = x.shape
    din, H, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    P = din // H
    K = cfg.conv_kernel
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din : 2 * din + 2 * N]
    dt_raw = zxbcdt[..., 2 * din + 2 * N :]
    conv_state = (state["conv"] if state is not None
                  else jnp.zeros((B, K - 1, din + 2 * N), x.dtype))
    xbc, conv_new = _depthwise_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xin = xbc[..., :din].reshape(B, S, H, P).astype(jnp.float32)
    B_ = xbc[..., din : din + N].astype(jnp.float32)
    C_ = xbc[..., din + N :].astype(jnp.float32)
    dt_ = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    la = -jnp.exp(p["A_log"])[None, None, :] * dt_       # log decay, < 0
    S0 = state["h"] if state is not None else jnp.zeros((B, H, P, N), jnp.float32)
    if kind == "decode":
        out, S_new = mamba_ssd_recurrent(xin, B_, C_, la, dt_, S0)
    else:
        out, S_new = mamba_ssd_chunked(xin, B_, C_, la, dt_, S0, cfg.chunk_size)
    out = out + p["D_skip"][None, None, :, None] * xin
    out = out.reshape(B, S, din).astype(x.dtype) * jax.nn.silu(z)
    out = rms_norm(out, p["gn_scale"], cfg.norm_eps)
    y = out @ p["out_proj"]
    new_state = None
    if kind in ("decode", "prefill"):
        new_state = {"h": S_new, "conv": conv_new.astype(jnp.bfloat16)}
    return x + y, new_state


def mamba_state_spec(cfg: ModelConfig, batch: int):
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = cfg.d_inner // H
    K = cfg.conv_kernel
    return {
        "h": jax.ShapeDtypeStruct((batch, H, P, N), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, K - 1, cfg.d_inner + 2 * N), jnp.bfloat16),
    }


def mamba_state_logical():
    return {"h": ("cache_batch", "act_heads", None, None),
            "conv": ("cache_batch", None, "dinner")}

"""Mixture-of-Experts: top-k router + two dispatch backends.

Backends (``config.moe_backend``):

  * ``einsum`` — capacity-based one-hot dispatch/combine einsums over token
    groups (Switch/MaxText style). Simple and robustly shardable, but the
    dispatch einsums cost ~2*T*E*C*D extra FLOPs — acceptable for few-expert
    models (grok-1: E=8, ~5% overhead), ruinous for deepseek-v2 (E=160,
    ~2x). The roofline's MODEL_FLOPS/HLO_FLOPs ratio exposes this.
  * ``gather`` — sort-based dispatch: argsort tokens by expert, build an
    (E, C) slot table, gather rows, batched per-expert GEMMs, scatter-add
    back. FLOPs-honest (capacity factor only); the default for large E.

Both backends drop tokens beyond capacity C = ceil(T*k/E * capacity_factor)
(standard dropping MoE); equivalence when nothing drops is property-tested.

Expert weights are sharded over the ``model`` mesh axis (expert parallelism);
`constrain` nudges XLA to all-to-all the dispatched blocks rather than
all-gathering expert weights.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.module import Param
from repro.models.layers import mlp_params, mlp_apply
from repro.runtime.sharding import constrain


def moe_params(cfg: ModelConfig) -> Dict[str, Any]:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = jnp.bfloat16
    p: Dict[str, Any] = {
        "router": Param((D, E), ("embed", None), jnp.float32, "fan_in"),
        "w_gate": Param((E, D, F), ("experts", "embed", "ff"), dt, "fan_in"),
        "w_up": Param((E, D, F), ("experts", "embed", "ff"), dt, "fan_in"),
        "w_down": Param((E, F, D), ("experts", "ff", "embed"), dt, "fan_in"),
    }
    if cfg.num_shared_experts:
        import dataclasses
        shared_cfg = dataclasses.replace(
            cfg, d_ff=cfg.num_shared_experts * F)
        p["shared"] = mlp_params(shared_cfg)
    return p


def _route(p, x_flat, cfg: ModelConfig):
    """Returns (ids (T,k), weights (T,k) fp32, aux_loss)."""
    k, E = cfg.experts_per_tok, cfg.num_experts
    logits = (x_flat.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)          # deepseek: softmax->topk
    if cfg.name.startswith("grok"):                 # grok: topk->softmax
        top_logits, ids = jax.lax.top_k(logits, k)
        weights = jax.nn.softmax(top_logits, axis=-1)
    # load-balance auxiliary (Switch): E * sum_e f_e * P_e
    T = x_flat.shape[0]
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)       # (T,k,E)
    f = jnp.sum(onehot, axis=(0, 1)) / jnp.maximum(T * k, 1)
    P = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * P)
    return ids, weights, aux


def _expert_ffn(p, h, cfg: ModelConfig):
    """h: (E, C, D) -> (E, C, D), batched per-expert gated FFN."""
    act = jax.nn.silu if cfg.mlp_type == "swiglu" else (
        lambda t: jax.nn.gelu(t, approximate=True))
    g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", act(g) * u, p["w_down"])


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = math.ceil(tokens * cfg.experts_per_tok / cfg.num_experts
                  * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to sublane multiple


# --------------------------------------------------------------------------
# einsum backend (capacity one-hot, grouped)
# --------------------------------------------------------------------------
def _moe_einsum(p, x_flat, cfg: ModelConfig):
    T, D = x_flat.shape
    E, k = cfg.num_experts, cfg.experts_per_tok
    Tg = min(T, 2048)
    n_groups = -(-T // Tg)
    pad = n_groups * Tg - T
    xg = jnp.pad(x_flat, ((0, pad), (0, 0))).reshape(n_groups, Tg, D)

    def group(carry, xt):
        ids, w, aux = _route(p, xt, cfg)
        C = _capacity(cfg, Tg)
        oe = jax.nn.one_hot(ids, E, dtype=jnp.int32)             # (Tg,k,E)
        # position of each (token, slot) within its expert
        flat = oe.reshape(Tg * k, E)
        pos = jnp.cumsum(flat, axis=0) * flat                    # (Tg*k,E)
        pos_tok = (jnp.sum(pos, axis=-1) - 1).reshape(Tg, k)     # (Tg,k)
        keep = (pos_tok < C) & (pos_tok >= 0)
        oc = jax.nn.one_hot(jnp.where(keep, pos_tok, C), C, dtype=x_flat.dtype)
        oe_f = oe.astype(x_flat.dtype)
        dispatch = jnp.einsum("tke,tkc->tec", oe_f, oc)          # (Tg,E,C)
        combine = jnp.einsum("tke,tkc,tk->tec", oe_f, oc, w.astype(x_flat.dtype))
        h = jnp.einsum("td,tec->ecd", xt, dispatch)
        h = constrain(h, ("experts", None, None))
        out = _expert_ffn(p, h, cfg)
        y = jnp.einsum("ecd,tec->td", out, combine)
        return carry + aux, y

    aux, yg = jax.lax.scan(group, jnp.float32(0.0), xg)
    y = yg.reshape(n_groups * Tg, D)[:T]
    return y, aux / n_groups


# --------------------------------------------------------------------------
# gather backend (sort-based, FLOPs-honest)
# --------------------------------------------------------------------------
def _moe_gather(p, x_flat, cfg: ModelConfig):
    T, D = x_flat.shape
    E, k = cfg.num_experts, cfg.experts_per_tok
    C = _capacity(cfg, T)
    ids, w, aux = _route(p, x_flat, cfg)
    flat_e = ids.reshape(-1)                                     # (T*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts                         # exclusive
    rank_sorted = jnp.arange(T * k) - starts[sorted_e]
    # invert the permutation: rank of each original slot in its expert
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    valid = rank < C
    # slot table: (E, C) -> originating flat slot (T*k = token*k + kth)
    table = jnp.full((E, C), T * k, jnp.int32)
    table = table.at[flat_e, rank].set(jnp.arange(T * k, dtype=jnp.int32),
                                       mode="drop")
    tok_of_slot = jnp.minimum(table // k, T - 1)
    live = table < T * k
    h = jnp.where(live[..., None], x_flat[tok_of_slot], 0)       # (E,C,D)
    h = constrain(h, ("experts", None, None))
    out = _expert_ffn(p, h, cfg)
    # combine: gather each (token, kth) slot's output and weight it
    g = out[flat_e.reshape(T, k), jnp.minimum(rank, C - 1).reshape(T, k)]  # (T,k,D)
    g = jnp.where(valid.reshape(T, k)[..., None], g, 0)
    y = jnp.einsum("tkd,tk->td", g, w.astype(g.dtype))
    return y.astype(x_flat.dtype), aux


def moe_apply(p, x, *, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (y, aux_loss)."""
    B, S, D = x.shape
    x_flat = x.reshape(B * S, D)
    if cfg.moe_backend == "einsum":
        y, aux = _moe_einsum(p, x_flat, cfg)
    elif cfg.moe_backend == "gather":
        y, aux = _moe_gather(p, x_flat, cfg)
    else:
        raise ValueError(f"unknown moe backend {cfg.moe_backend}")
    y = y.reshape(B, S, D)
    if cfg.num_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg=cfg)
    return y, aux

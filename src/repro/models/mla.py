"""Multi-head Latent Attention (DeepSeek-V2).

Train/prefill run the standard decompressed path. Decode runs the *absorbed*
path (q-side absorption of the k up-projection, output-side absorption of the
v up-projection), attending directly over the compressed (c_kv, k_rope) cache
— this is what makes a 524k-token-free... rather, 32k x 128-batch decode
feasible: the cache holds (kv_lora + rope) = 576 dims per token instead of
n_heads*(192+128).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import NEG_INF, rms_norm, rms_norm_params, rope
from repro.models.module import Param
from repro.runtime.sharding import constrain


def mla_params(cfg: ModelConfig) -> Dict[str, Any]:
    D, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = jnp.bfloat16
    return {
        "wq_a": Param((D, qr), ("embed", "q_rank"), dt, "fan_in"),
        "q_norm": rms_norm_params(qr),
        "wq_b": Param((qr, H, dn + dr), ("q_rank", "heads", "head_dim"), dt, "fan_in"),
        "wkv_a": Param((D, kvr + dr), ("embed", "kv_rank"), dt, "fan_in"),
        "kv_norm": rms_norm_params(kvr),
        "wkv_b": Param((kvr, H, dn + dv), ("kv_rank", "heads", "head_dim"), dt, "fan_in"),
        "wo": Param((H, dv, D), ("heads", "head_dim", "embed"), dt, "fan_in"),
    }


def _project_q(p, x, cfg: ModelConfig, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_c = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("btr,rhk->bthk", q_c, p["wq_b"])
    q = constrain(q, ("batch", None, "act_heads", None))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _compress_kv(p, x, cfg: ModelConfig, positions):
    kvr, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = x @ p["wkv_a"]
    c_kv = rms_norm(kv[..., :kvr], p["kv_norm"], cfg.norm_eps)
    k_rope = rope(kv[..., kvr:][:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_apply(
    p,
    x,
    *,
    cfg: ModelConfig,
    positions,
    kind: str,
    cache: Optional[Dict[str, Any]] = None,
    max_seq: Optional[int] = None,
    paged: Optional[Tuple] = None,
):
    """Returns (y, new_cache). Cache: {"c_kv": (B,Smax,kvr), "k_rope":
    (B,Smax,dr), "idx": ()} — compressed, per the MLA design.

    kind="paged_decode" consumes a PAGED compressed cache: {"c_kv":
    (NP, P, kvr), "k_rope": (NP, P, dr) physical page frames, "idx": (B,)},
    with the logical->physical map in `paged`; the absorbed attention runs
    straight over the pages (kernels.pul_paged_mla_decode_attention) and the
    returned cache holds only the current token's compressed rows."""
    B, T, D = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)
    q_nope, q_rope = _project_q(p, x, cfg, positions)

    if kind == "paged_decode":
        assert T == 1, "paged decode processes one token per step"
        assert paged is not None, "paged_decode needs (page_table, PULConfig)"
        from repro.models.layers import PagedSweep
        idx = jnp.asarray(cache["idx"], jnp.int32).reshape(B)
        c_new, r_new = _compress_kv(p, x, cfg, positions)
        wkv_b_k = p["wkv_b"][..., :dn]                      # (kvr, H, dn)
        wkv_b_v = p["wkv_b"][..., dn:]                      # (kvr, H, dv)
        q_abs = jnp.einsum("bthn,rhn->bthr", q_nope, wkv_b_k)[:, 0]
        if isinstance(paged, PagedSweep):
            # single-sweep path over the full per-layer compressed planes;
            # the fused epilogue commits c_new/r_new to the tail page
            from repro.kernels.pul_attention import (
                pul_paged_sweep_mla_decode_attention)
            cp, rp = paged.plane("c_kv"), paged.plane("k_rope")
            c_new = c_new[:, 0].astype(cp.dtype)
            r_new = r_new[:, 0].astype(rp.dtype)
            o_c, cp, rp = pul_paged_sweep_mla_decode_attention(
                q_abs, q_rope[:, 0], cp, rp, paged.layer, paged.page_table,
                idx, c_new, r_new, paged.frames, paged.offsets, scale=scale,
                cfg=paged.pul_cfg)
            paged.set_plane("c_kv", cp)
            paged.set_plane("k_rope", rp)
        else:
            from repro.kernels.pul_attention import (
                pul_paged_mla_decode_attention)
            page_table, pul_cfg = paged
            c_new = c_new[:, 0].astype(cache["c_kv"].dtype)
            r_new = r_new[:, 0].astype(cache["k_rope"].dtype)
            o_c = pul_paged_mla_decode_attention(
                q_abs, q_rope[:, 0], cache["c_kv"], cache["k_rope"],
                page_table, idx, c_new, r_new, scale=scale, cfg=pul_cfg)
        out = jnp.einsum("bhr,rhv->bhv", o_c, wkv_b_v)[:, None]
        new_cache = {"c_kv": c_new, "k_rope": r_new, "idx": idx + 1}
    elif kind == "decode":
        # Per-slot fill levels (idx: (B,)) — see layers.attention_apply.
        assert T == 1, "decode processes one token per step"
        idx = jnp.broadcast_to(jnp.asarray(cache["idx"], jnp.int32), (B,))
        c_new, r_new = _compress_kv(p, x, cfg, positions)
        rows = jnp.arange(B)
        S = cache["c_kv"].shape[1]
        write = jax.lax.rem(idx, S)
        c_kv = cache["c_kv"].at[rows, write].set(
            c_new[:, 0].astype(cache["c_kv"].dtype))
        k_rope = cache["k_rope"].at[rows, write].set(
            r_new[:, 0].astype(cache["k_rope"].dtype))
        # absorbed path: q into compressed space; attend over (c_kv, k_rope)
        wkv_b_k = p["wkv_b"][..., :dn]                      # (kvr, H, dn)
        wkv_b_v = p["wkv_b"][..., dn:]                      # (kvr, H, dv)
        q_abs = jnp.einsum("bthn,rhn->bthr", q_nope, wkv_b_k)   # (B,T,H,kvr)
        logits = (
            jnp.einsum("bthr,bsr->bhts", q_abs, c_kv)
            + jnp.einsum("bthn,bsn->bhts", q_rope, k_rope)
        ).astype(jnp.float32) * scale
        mask = (jnp.arange(S)[None, None, None, :]
                <= idx[:, None, None, None])
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(c_kv.dtype)
        o_c = jnp.einsum("bhts,bsr->bthr", probs, c_kv)     # (B,T,H,kvr)
        out = jnp.einsum("bthr,rhv->bthv", o_c, wkv_b_v)    # (B,T,H,dv)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope, "idx": idx + 1}
    else:
        c_kv, k_rope = _compress_kv(p, x, cfg, positions)
        kv = jnp.einsum("btr,rhk->bthk", c_kv, p["wkv_b"])
        kv = constrain(kv, ("batch", None, "act_heads", None))
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, T, H, dr))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        from repro.models.layers import KV_BLOCK, _attend_chunked
        if T > 2 * KV_BLOCK:
            # nope/rope head dims differ from v head dim; the streaming core
            # only needs matching q/k dims, v dim is free
            out = _attend_chunked(q, k, v, softcap=None, scale=scale,
                                  window=None)
        else:
            logits = jnp.einsum("bthk,bshk->bhts", q, k).astype(jnp.float32) * scale
            mask = (jnp.arange(T)[:, None] >= jnp.arange(T)[None, :])[None, None]
            logits = jnp.where(mask, logits, NEG_INF)
            probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
            out = jnp.einsum("bhts,bshv->bthv", probs, v)
        new_cache = None
        if kind == "prefill":
            target = max_seq or T
            ckv_c, kr_c = c_kv, k_rope
            if target > T:
                ckv_c = jnp.pad(c_kv, ((0, 0), (0, target - T), (0, 0)))
                kr_c = jnp.pad(k_rope, ((0, 0), (0, target - T), (0, 0)))
            new_cache = {"c_kv": ckv_c.astype(jnp.bfloat16),
                         "k_rope": kr_c.astype(jnp.bfloat16),
                         "idx": jnp.full((B,), T, jnp.int32)}
    y = jnp.einsum("bthv,hvd->btd", out, p["wo"])
    return y, new_cache


def mla_cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_seq, cfg.kv_lora_rank), jnp.bfloat16),
        "k_rope": jax.ShapeDtypeStruct((batch, max_seq, cfg.qk_rope_head_dim), jnp.bfloat16),
        "idx": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def mla_cache_logical():
    return {
        "c_kv": ("cache_batch", "cache_seq", "kv_rank"),
        "k_rope": ("cache_batch", "cache_seq", "kv_rank"),
        "idx": ("cache_batch",),
    }

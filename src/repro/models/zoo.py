"""build_model(config): the zoo's single entry point.

Wraps transformer.py into a Model record with bound apply fns, abstract
parameter/cache trees, and per-shape input_specs (ShapeDtypeStructs for the
dry-run; the modality frontends are stubs supplying precomputed embeddings
per the assignment).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as T
from repro.models import module as M


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    params: Any                   # Param tree (abstract)
    loss: Callable                # (params, batch) -> scalar
    prefill: Callable             # (params, batch) -> (logits, caches)
    decode_step: Callable         # (params, batch, caches) -> (logits, caches)
    paged_decode_step: Callable   # (params, batch, page-view caches) ->
                                  # (logits, new-token rows + state)

    def init(self, key):
        return M.init_tree(key, self.params)

    def abstract_params(self):
        return M.abstract_tree(self.params)

    def num_params(self) -> int:
        return M.count_params(self.params)

    def cache_specs(self, batch: int, max_seq: int):
        return T.cache_specs(self.cfg, batch, max_seq)


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        params=T.model_params(cfg),
        loss=functools.partial(T.loss_fn, cfg=cfg),
        prefill=functools.partial(T.prefill_fn, cfg=cfg),
        decode_step=functools.partial(T.decode_fn, cfg=cfg),
        paged_decode_step=functools.partial(T.paged_decode_fn, cfg=cfg),
    )


# --------------------------------------------------------------------------
# input specs (dry-run stand-ins; also documents the data contract)
# --------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Returns {"batch": tree, "batch_logical": tree[, "caches",
    "caches_logical"]} for the given (arch x shape) cell.

    The modality frontend STUB manifests here: internvl2 receives 256
    precomputed ViT patch embeddings, musicgen 64 conditioning frames; token
    count shrinks so total sequence stays shape.seq_len.
    """
    B, S = shape.global_batch, shape.seq_len
    Tf = cfg.frontend_tokens if cfg.frontend else 0
    St = S - Tf
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        batch = {
            "tokens": _sds((B, St), jnp.int32),
            "targets": _sds((B, St), jnp.int32),
            "loss_mask": _sds((B, St), jnp.float32),
        }
        logical = {
            "tokens": ("batch", "seq"),
            "targets": ("batch", "seq"),
            "loss_mask": ("batch", "seq"),
        }
    elif shape.kind == "prefill":
        batch = {"tokens": _sds((B, St), jnp.int32)}
        logical = {"tokens": ("batch", "seq")}
    elif shape.kind == "decode":
        batch = {
            "tokens": _sds((B, 1), jnp.int32),
            "pos0": _sds((B,), jnp.int32),
        }
        logical = {"tokens": ("batch", None), "pos0": ("batch",)}
        caches, caches_logical = T.cache_specs(cfg, B, S)
        out["caches"] = caches
        out["caches_logical"] = caches_logical
    else:
        raise ValueError(shape.kind)
    if Tf and shape.kind != "decode":
        batch["frontend_embeds"] = _sds((B, Tf, cfg.d_model), jnp.bfloat16)
        logical["frontend_embeds"] = ("batch", "seq", None)
    out["batch"] = batch
    out["batch_logical"] = logical
    return out


def demo_batch(cfg: ModelConfig, batch_size: int, seq_len: int, key=None):
    """Concrete small batch for smoke tests / examples (train kind)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    Tf = cfg.frontend_tokens if cfg.frontend else 0
    tokens = jax.random.randint(k1, (batch_size, seq_len), 0, cfg.vocab_size,
                                jnp.int32)
    batch = {
        "tokens": tokens,
        "targets": jnp.roll(tokens, -1, axis=1),
        "loss_mask": jnp.ones((batch_size, seq_len), jnp.float32),
    }
    if Tf:
        batch["frontend_embeds"] = (
            jax.random.normal(k2, (batch_size, Tf, cfg.d_model), jnp.float32)
            .astype(jnp.bfloat16) * 0.02)
    return batch

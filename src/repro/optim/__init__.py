from repro.optim.optimizer import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)
from repro.optim import compression

__all__ = ["OptimizerConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "compression"]

"""AdamW with sharded fp32 moments, global-norm clipping, cosine schedule.

Memory policy (large-scale posture): parameters live in bf16, Adam moments in
fp32, updates computed in fp32 then cast — 10 bytes/param total, which is
what lets grok-1-314B fit 256 chips (see DESIGN.md §4). Moment tensors
inherit the parameter PartitionSpecs (ZeRO-3: optimizer state is sharded
exactly like its parameter).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params, moment_dtype=jnp.float32) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(grads, state, params, cfg: OptimizerConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        mdt = m.dtype                      # fp32 or bf16 (giant models)
        g = g.astype(jnp.float32) * scale
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay (skip 1-d tensors: norms/biases)
        if p.ndim > 1:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m.astype(mdt), v.astype(mdt)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr, "step": step}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics

"""Gradient compression for scarce cross-pod links.

Two mechanisms, matching what is actually deployable under SPMD:

1. **bf16 reduction (default-on)**: the train step keeps activations/grads in
   bf16, so every SPMD-inserted all-reduce/reduce-scatter moves 2 bytes per
   element instead of 4. This is implicit compression and costs nothing.

2. **Error-feedback int8 all-reduce (opt-in)**: ``ef_psum`` — a shard_map
   collective that quantizes each gradient block to int8 with a per-block
   fp32 scale before summing over the (cross-pod) axis, carrying the
   quantization residual into the next step (error feedback keeps the
   optimizer unbiased in expectation). Used by the data-parallel trainer
   path (`launch/train.py --compress-grads`) where gradients are reduced
   explicitly; the fully-automatic pjit path keeps SPMD's own reductions
   (documented trade-off: XLA cannot currently be told to quantize the
   collectives it inserts).

The quantize/dequantize pair is also the unit of the PUL unload analogy at
the framework level: results are shrunk before being pushed over the slow
link, like the paper's bit-vector materialization (Exp. 5).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_quantize(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback quantize: q(g + err); new_err = (g + err) - deq(q)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale)
    return q, scale, corrected - deq


def ef_psum(g: jax.Array, err: jax.Array, axis_name: str
            ) -> Tuple[jax.Array, jax.Array]:
    """Quantized psum over `axis_name` with error feedback.

    Must be called inside shard_map with `axis_name` bound. int8 payloads are
    summed in int32 (no overflow below 2^23 participants); scales are
    max-combined (conservative shared scale).
    """
    corrected = g.astype(jnp.float32) + err
    # agree on a shared scale so the sum is exact in the quantized domain
    amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_name)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_err = corrected - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, new_err


def ef_psum_tree(grads, errs, axis_name: str):
    """Tree version; returns (reduced grads fp32, new error state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out = [ef_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    red = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in out])
    return red, new_e


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

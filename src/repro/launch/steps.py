"""Jittable step functions + their sharding contracts.

One place defines what runs on the mesh: ``train_step`` (fwd+bwd+AdamW),
``prefill_step`` and ``decode_step`` (serving). `step_shardings` resolves
every input's PartitionSpec from logical axes so dryrun/train/serve all agree.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import zoo
from repro.models import module as M
from repro.optim import OptimizerConfig, adamw_init, adamw_update
from repro.runtime.sharding import ShardingRules, logical_to_spec


def make_train_step(cfg: ModelConfig,
                    opt_cfg: OptimizerConfig = OptimizerConfig(),
                    accum: int = 1):
    """fwd + bwd + AdamW. ``accum`` > 1 scans microbatches with gradient
    accumulation: live activation memory shrinks by `accum` at zero
    communication cost (the memory-roofline knob of §Perf)."""
    model = zoo.build_model(cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss)(params, batch)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)

            def acc_body(carry, mb):
                loss_sum, g_sum = carry
                loss, g = grads_of(params, mb)
                g_sum = jax.tree.map(jnp.add, g_sum, g)
                return (loss_sum + loss, g_sum), ()

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0.0), g0), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        new_params, new_opt, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: Optional[int] = None):
    model = zoo.build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_seq=max_seq)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    model = zoo.build_model(cfg)

    def decode_step(params, batch, caches):
        return model.decode_step(params, batch, caches)

    return decode_step


# --------------------------------------------------------------------------
# sharding contracts
# --------------------------------------------------------------------------
def _spec_from_logical_tree(logical_tree, shape_tree, mesh, rules):
    return jax.tree.map(
        lambda logical, s: logical_to_spec(logical, s.shape, mesh, rules),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def abstract_state(cfg: ModelConfig):
    """Abstract (params, opt_state) ShapeDtypeStructs — no allocation."""
    model = zoo.build_model(cfg)
    aparams = model.abstract_params()
    mdt = jnp.bfloat16 if cfg.bf16_moments else jnp.float32
    opt = {
        "m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, mdt), aparams),
        "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, mdt), aparams),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return aparams, opt


def state_specs(cfg: ModelConfig, mesh, rules: ShardingRules = ShardingRules()):
    model = zoo.build_model(cfg)
    pspecs = M.param_specs(model.params, mesh, rules)
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    return pspecs, ospecs


SERVE_REPLICATE_LIMIT = 12 * 2**30   # bf16 weights per device after TP


def cell_specs(cfg: ModelConfig, shape: InputShape, mesh,
               rules: Optional[ShardingRules] = None):
    """Everything dryrun/train/serve need for one (arch x shape) cell:
    abstract inputs + PartitionSpecs, keyed by the step kind.

    Inference cells replicate weights across the data axes when they fit
    (TP-only sharding): FSDP-sharded weights would be re-gathered over ICI
    on EVERY decode step, which made serving collective-bound (§Perf A).
    Giant models (deepseek, grok) keep FSDP — they don't fit replicated."""
    if rules is None:
        rules = ShardingRules()
        if shape.kind != "train":
            from repro.models import module as _M
            model = zoo.build_model(cfg)
            tp = mesh.shape.get("model", 1)
            if _M.count_bytes(model.params) / tp <= SERVE_REPLICATE_LIMIT:
                rules = rules.with_overrides(embed=(None,))
    ins = zoo.input_specs(cfg, shape)
    batch_specs = _spec_from_logical_tree(ins["batch_logical"], ins["batch"],
                                          mesh, rules)
    out = {"batch": ins["batch"], "batch_specs": batch_specs}
    if shape.kind == "train":
        aparams, aopt = abstract_state(cfg)
        pspecs, ospecs = state_specs(cfg, mesh, rules)
        out.update(params=aparams, opt=aopt, param_specs=pspecs, opt_specs=ospecs)
    else:
        aparams, _ = abstract_state(cfg)
        pspecs, _ = state_specs(cfg, mesh, rules)
        out.update(params=aparams, param_specs=pspecs)
    if shape.kind == "decode":
        out["caches"] = ins["caches"]
        out["cache_specs"] = _spec_from_logical_tree(
            ins["caches_logical"], ins["caches"], mesh, rules)
    return out

"""Serving launcher: batched engine over any zoo architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import zoo
from repro.serving import EngineConfig, Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = zoo.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        batch_slots=args.slots, max_seq=args.max_seq,
        prefill_bucket=min(64, args.max_seq // 2)))

    rng = jax.random.PRNGKey(1)
    import numpy as np
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, size=(args.requests, 8)).tolist()
    t0 = time.time()
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=args.max_new))
    out = eng.run()
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    for rid, toks in sorted(out.items()):
        print(f"[serve] req {rid}: {toks}")
    print(f"[serve] {total} tokens in {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s, {args.slots} slots)")


if __name__ == "__main__":
    main()

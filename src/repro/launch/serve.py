"""Serving launcher: paged, PUL-tiered continuous batching over the zoo.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 8 --max-new 12 --page-tokens 8 --slots 4

`--dense` falls back to the monolithic-cache reference engine. Page-pool
knobs: --page-tokens (page size), --hot-pages (fast-tier frames; 0 = fit
everything), --distance (preload distance for page restores; 0 = planner
d*). Scheduling knobs: --policy (fcfs | priority | slo-edf; the latter two
preempt running requests, swapping their pages to the cold tier),
--prefill-chunk (page-aligned chunked prefill so long prompts don't stall
decode), --high-priority-every / --ttft-deadline to shape a mixed-urgency
workload. A per-tick metrics line reports tokens/s, page faults,
shared-prefix hits, and the modeled fraction of restore latency the
preload plan hides.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import zoo
from repro.obs import Tracer, validate_chrome_trace
from repro.serving import (
    PagedServingEngine,
    Request,
    ServingConfig,
    ServingEngine,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--dense", action="store_true",
                    help="use the dense-cache reference engine")
    ServingConfig.add_flags(ap)
    ap.add_argument("--high-priority-every", type=int, default=0,
                    help="mark every Nth request high-priority with a TTFT "
                         "deadline (0 = uniform workload)")
    ap.add_argument("--ttft-deadline", type=int, default=8,
                    help="TTFT deadline in ticks for high-priority requests")
    ap.add_argument("--log-every", type=int, default=8)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record a unified Chrome/Perfetto trace of the run "
                         "(engine spans, scheduler decisions, page "
                         "lifecycle, DMA twin) to PATH")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="dump the final metrics registry (engine counters "
                         "+ cache economics) to PATH — Prometheus text for "
                         ".prom, JSON otherwise")
    args = ap.parse_args(argv)
    if args.dense and (args.trace or args.metrics):
        ap.error("--trace/--metrics instrument the paged engine; "
                 "drop --dense")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = zoo.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # ONE config for both engines: each projects the facade onto its layer
    serving_cfg = ServingConfig.from_flags(args)
    if args.dense:
        eng = ServingEngine(cfg, params, serving_cfg)
    else:
        hook = (lambda s: print(
            f"[serve] tick {s['tick']:4d}  {s['tokens_per_sec']:6.1f} tok/s"
            f"  live {s['live_slots']}  queued {s['queued']}"
            f"  faults {s['page_faults']}  shared {s['shared_page_hits']}"
            f"  hidden {s['modeled_restore_latency_hidden']:.0%}")
            if s["tick"] % args.log_every == 0 else None)
        tracer = Tracer() if args.trace else None
        eng = PagedServingEngine(cfg, params, serving_cfg,
                                 metrics_hook=hook, tracer=tracer)
        print(f"[serve] paged KV: {eng.layout.features} packed features/token"
              f", {args.page_tokens} tokens/page, planned d*="
              f"{eng.pool.distance}")

    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, size=(args.requests, 8)).tolist()
    t0 = time.time()
    for i, p in enumerate(prompts):
        hp = args.high_priority_every and (i % args.high_priority_every == 0)
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=args.max_new,
                           priority=1 if hp else 0,
                           ttft_deadline=args.ttft_deadline if hp else -1))
    out = eng.run()
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    for rid, toks in sorted(out.items()):
        print(f"[serve] req {rid}: {toks}")
    print(f"[serve] {total} tokens in {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s, {args.slots} slots)")
    if not args.dense:
        snap = eng.snapshot()
        print(f"[serve] pages allocated {snap['pages_allocated']}, faults "
              f"{snap['page_faults']}, evictions {snap['evictions']}, "
              f"shared hits {snap['shared_page_hits']}, mean queue wait "
              f"{snap['mean_queue_latency']:.1f} ticks")
        print(f"[serve] policy {snap['policy']}: preemptions "
              f"{snap['preemptions']}, readmissions {snap['readmissions']}, "
              f"chunk passes {snap['chunk_passes']}, SLO violations "
              f"{snap['slo_violations']}, rejected {snap['rejected']}")
        econ = eng.economics()
        for tier, t in econ["tiers"].items():
            print(f"[serve] {tier} tier: {t['bytes_moved']} bytes moved "
                  f"({t['bytes_per_token']:.0f} B/token)")
        if args.trace:
            doc = eng.tracer.to_chrome(args.trace)
            errs = validate_chrome_trace(doc)
            assert not errs, "\n".join(errs)
            print(f"[serve] trace: {len(doc['traceEvents'])} events -> "
                  f"{args.trace} (load in ui.perfetto.dev, or "
                  "tools/trace_view.py)")
        if args.metrics:
            reg = eng.metrics_registry()
            if args.metrics.endswith(".prom"):
                reg.dump_prometheus(args.metrics)
            else:
                reg.dump_json(args.metrics)
            print(f"[serve] metrics -> {args.metrics}")


if __name__ == "__main__":
    main()

"""Production trainer: mesh + sharded state + PUL data pipeline + async
checkpointing + fault-tolerant restart.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

`--reduced` runs the smoke-size config on local devices (CPU-friendly);
full-size runs expect a real TPU slice (same code path, bigger mesh).
Restart semantics: rerunning the same command resumes from the latest
committed checkpoint and skips the data stream to the restored step.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.launch import mesh as mesh_lib
from repro.launch import steps as S
from repro.models import module as M
from repro.models import zoo
from repro.optim import OptimizerConfig, adamw_init
from repro.runtime.fault import HeartbeatMonitor
from repro.runtime.sharding import ShardingRules, logical_to_spec
from jax.sharding import NamedSharding


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 => (data=2, model=4) over local devices")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = zoo.build_model(cfg)

    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    else:
        mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))

    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(1, args.steps // 20))
    train_step = S.make_train_step(cfg, opt_cfg, accum=args.accum)

    with mesh_lib.set_mesh(mesh):
        pspecs = M.param_specs(model.params, mesh)
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                              is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval"))
        params = jax.jit(model.init, out_shardings=pshard)(jax.random.PRNGKey(0))
        import jax.numpy as jnp
        mdt = jnp.bfloat16 if cfg.bf16_moments else jnp.float32
        opt_state = jax.jit(lambda p: adamw_init(p, mdt))(params)

        data = TokenPipeline(DataConfig(
            global_batch=args.batch, seq_len=args.seq,
            vocab_size=cfg.vocab_size, frontend_tokens=cfg.frontend_tokens,
            d_model=cfg.d_model, prefetch_distance=2))

        start = 0
        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(CheckpointConfig(args.ckpt_dir))
            if mgr.latest_step() is not None:
                start, (params, opt_state) = mgr.restore(
                    like=(params, opt_state))
                print(f"[train] resumed from step {start}")
        data.skip_to(start)
        data.start()

        jstep = jax.jit(train_step, donate_argnums=(0, 1))
        hb = HeartbeatMonitor()
        t_last = time.time()
        for step in range(start, args.steps):
            batch = next(data)
            params, opt_state, metrics = jstep(params, opt_state, batch)
            if (step + 1) % args.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                dt = time.time() - t_last
                t_last = time.time()
                hb.beat("worker0", dt)
                print(f"[train] step {step + 1} loss {loss:.4f} "
                      f"({dt / args.log_every:.3f}s/step)")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, (params, opt_state))   # async unload
        if mgr:
            mgr.save(args.steps, (params, opt_state), block=True)
        data.stop()
        print("[train] done; final loss",
              float(metrics["loss"]) if args.steps > start else "n/a")


if __name__ == "__main__":
    main()

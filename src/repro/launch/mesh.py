"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import; smoke tests and benches see the 1 real CPU device.

Axes:
  pod   — cross-pod data parallelism (2 pods in the multi-pod dry-run)
  data  — in-pod data parallelism / FSDP sharding
  model — tensor/expert parallelism
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """Version-compat mesh context: `jax.set_mesh` landed after 0.4.37;
    on older jax the Mesh object itself is the context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Tiny mesh for in-test dry-runs (requires >= n_data*n_model devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_chips(mesh) -> int:
    out = 1
    for v in mesh.shape.values():
        out *= v
    return out

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices form the production meshes; inputs are ShapeDtypeStructs (no
allocation); ``.lower().compile()`` must succeed and the compiled artifact
yields memory_analysis (fits?), cost_analysis (FLOPs/bytes) and the HLO
collective schedule — the inputs to the §Roofline report.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import json
import re
import sys
import time
import traceback
from collections import Counter
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import CONFIGS, SHAPES, get_config
from repro.launch import mesh as mesh_lib
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh, mesh_chips

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)\s+)?[\w:\[\]{}, ]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|f16|c64|c128)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _result_bytes(line: str) -> int:
    """Sum byte size of the result shape(s) left of '=' on an HLO line."""
    lhs = line.split(" = ", 1)[0] if " = " in line else ""
    rhs = line.split(" = ", 1)[1] if " = " in line else line
    # result shape(s) are the first shape token(s) on the rhs, before opcode
    head = rhs.split("(", 1)[0]
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(head):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _DTYPE_BYTES.get(dt, 4)
    return nbytes


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))        # [n_groups, group_size]<=[N]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


_META_RE = re.compile(r'op_name="([^"]*)"')


def collective_bytes_from_hlo(hlo: str, n_devices: int):
    """Per-device wire bytes of every collective (per-partition HLO).

    Operand shapes are not printed inline by this XLA version, so byte
    counts derive from the RESULT shape + replica group size g per the
    standard ring costs:
      all-gather       (g-1)/g * result      (result = gathered buffer)
      reduce-scatter   (g-1)   * result      (result = scattered shard)
      all-reduce       2(g-1)/g * result
      all-to-all       (g-1)/g * result
      collective-permute        result
    `-done` ops are skipped (they would double-count their `-start`).

    Returns (static_total, per_kind, by_depth) where by_depth maps the
    lax.scan nesting depth (count of "/while/" in the op metadata) to bytes.
    XLA executes a loop body once per trip, so the roofline multiplies
    depth-d bytes by the enclosing trip counts (accum, num_groups, ...) —
    the static sum alone undercounts scanned collectives."""
    per_kind = Counter()
    by_depth = Counter()
    total = 0.0
    for line in hlo.splitlines():
        if "-done(" in line or "-done.1" in line:
            continue
        m = _COLL_RE.search(line)
        if m is None:
            continue
        kind = m.group(1)
        g = _group_size(line, n_devices)
        rb = _result_bytes(line)
        if g <= 1:
            continue
        if kind == "all-gather":
            nb = rb * (g - 1) / g
        elif kind == "reduce-scatter":
            nb = rb * (g - 1)
        elif kind == "all-reduce":
            nb = rb * 2 * (g - 1) / g
        elif kind == "all-to-all":
            nb = rb * (g - 1) / g
        else:  # collective-permute
            nb = rb
        meta = _META_RE.search(line)
        depth = meta.group(1).count("/while/") if meta else 0
        by_depth[depth] += int(nb)
        per_kind[kind] += int(nb)
        total += nb
    return int(total), dict(per_kind), dict(by_depth)


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                verbose: bool = True, accum: int = 0, variant: str = "",
                moe_backend: str = ""):
    """`variant` selects sharding experiments for the §Perf hillclimbs:
      serve_replicate   — inference weights replicated over (pod,data), TP
                          only over model (kills the per-step FSDP gather;
                          valid when params_bf16/16 fits HBM)
      cache_seq_data    — decode KV cache sequence NOT sharded over the
                          model axis (the pre-fix baseline of §Perf C)
    """
    cfg = get_config(arch)
    if moe_backend:
        import dataclasses
        cfg = dataclasses.replace(cfg, moe_backend=moe_backend)
    shape = SHAPES[shape_name]
    if not cfg.shape_applicable(shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "pure full-attention arch; long_500k requires "
                          "sub-quadratic attention (DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    from repro.runtime.sharding import ShardingRules
    rules = None                        # cell_specs applies serve-replication
    if variant == "serve_replicate" and shape.kind != "train":
        rules = ShardingRules().with_overrides(embed=(None,))
    elif variant == "serve_fsdp":       # §Perf A baseline: FSDP'd weights
        rules = ShardingRules()
    elif variant == "cache_seq_data":   # §Perf C baseline
        rules = ShardingRules().with_overrides(cache_seq=("data", None))
    t0 = time.time()
    with mesh_lib.set_mesh(mesh):
        cell = S.cell_specs(cfg, shape, mesh, rules)
        if shape.kind == "train":
            # microbatch so activations fit HBM; recorded for §Perf
            accum = accum or cfg.train_accum
            while shape.global_batch % accum:
                accum //= 2
            fn = S.make_train_step(cfg, accum=accum)
            in_shardings = (cell["param_specs"], cell["opt_specs"],
                            cell["batch_specs"])
            args = (cell["params"], cell["opt"], cell["batch"])
            donate = (0, 1)
        elif shape.kind == "prefill":
            fn = S.make_prefill_step(cfg, max_seq=shape.seq_len)
            in_shardings = (cell["param_specs"], cell["batch_specs"])
            args = (cell["params"], cell["batch"])
            donate = ()
        else:  # decode
            fn = S.make_decode_step(cfg)
            in_shardings = (cell["param_specs"], cell["batch_specs"],
                            cell["cache_specs"])
            args = (cell["params"], cell["batch"], cell["caches"])
            donate = (2,)
        jfn = jax.jit(fn, in_shardings=in_shardings, donate_argnums=donate)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll_total, coll_kinds, coll_depth = collective_bytes_from_hlo(hlo, chips)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "variant": variant or "default",
        "chips": chips,
        "step_kind": shape.kind,
        "accum": accum if shape.kind == "train" else None,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # per-device numbers (SPMD per-partition module)
        "argument_bytes_per_dev": int(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes_per_dev": int(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes_per_dev": int(getattr(ma, "temp_size_in_bytes", 0)),
        "peak_bytes_per_dev": int(getattr(ma, "temp_size_in_bytes", 0))
        + int(getattr(ma, "argument_size_in_bytes", 0)),
        "flops_per_dev": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_dev": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes_per_dev": int(coll_total),
        "collective_kinds": coll_kinds,
        "collective_bytes_by_depth": {str(k): v for k, v in coll_depth.items()},
        "hlo_ops": {
            k: hlo.count(k) for k in
            ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute", "dynamic-slice", "fusion")
        },
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x "
              f"{'multi' if multi_pod else 'single'}: "
              f"compile={t_compile:.1f}s "
              f"args/dev={result['argument_bytes_per_dev']/2**30:.2f}GiB "
              f"temp/dev={result['temp_bytes_per_dev']/2**30:.2f}GiB "
              f"flops/dev={result['flops_per_dev']:.3e} "
              f"coll/dev={coll_total/2**20:.1f}MiB")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch, shape) cell on both meshes")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--accum", type=int, default=0)
    ap.add_argument("--variant", default="")
    ap.add_argument("--moe-backend", default="")
    args = ap.parse_args(argv)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        meshes = args.meshes.split(",")
        archs = [args.arch] if args.arch else list(CONFIGS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        failures = 0
        for arch in archs:
            for shape_name in shapes:
                for mesh_name in meshes:
                    tag = f"{arch}__{shape_name}__{mesh_name}"
                    fp = outdir / f"{tag}.json"
                    if fp.exists():
                        print(f"[dryrun] {tag}: cached")
                        continue
                    try:
                        res = dryrun_cell(arch, shape_name,
                                          multi_pod=(mesh_name == "multi"),
                                          accum=args.accum)
                    except (KeyboardInterrupt, SystemExit):
                        # never swallow an interrupt into an "error" cell:
                        # the sweep must stop, not record a bogus failure
                        raise
                    except Exception as e:
                        traceback.print_exc()
                        print(f"[dryrun] {tag}: swallowed "
                              f"{type(e).__name__} ({e}); recorded as an "
                              "error cell and continuing the sweep",
                              file=sys.stderr)
                        res = {"arch": arch, "shape": shape_name,
                               "mesh": mesh_name, "status": "error",
                               "error": f"{type(e).__name__}: {e}"}
                        failures += 1
                    fp.write_text(json.dumps(res, indent=2))
        sys.exit(1 if failures else 0)
    else:
        res = dryrun_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                          accum=args.accum, variant=args.variant,
                          moe_backend=args.moe_backend)
        print(json.dumps(res, indent=2))
        tag = f"{res['arch']}__{res['shape']}__{res['mesh']}"
        if args.variant or args.moe_backend:
            tag += f"__{args.variant or args.moe_backend}"
        (outdir / f"{tag}.json").write_text(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()

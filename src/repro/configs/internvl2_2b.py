"""InternVL2-2B — InternLM2-1.8B language backbone + InternViT frontend.

[arXiv:2404.16821; hf]. 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553. The ViT frontend is a STUB per the assignment: `input_specs()`
supplies precomputed patch embeddings (B, 256, d_model) that are prepended to
the token stream (vlm family).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    pattern=("global",),
    train_accum=2,
    mlp_type="swiglu",
    rope_theta=1e6,
    frontend="vit_stub",
    frontend_tokens=256,
)

"""Gemma2-27B — local/global alternating attention, logit softcaps.

[arXiv:2408.00118; hf]. 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000. head_dim 128, sliding window 4096 on local layers, attention
logit softcap 50, final logit softcap 30, GeGLU MLP, tied embeddings scaled
by sqrt(d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    pattern=("local", "global"),
    train_accum=8,
    mlp_type="geglu",
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    rope_theta=1e4,
    tie_embeddings=True,
    embed_scale=True,
    sandwich_norm=True,
)

"""Zamba2-7B — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; unverified]. 81L d_model=3584 32H (MHA) d_ff=14336
vocab=32000, ssm_state=64. Pattern: (mamba, mamba, shared_attn) x 27 — the
attention+MLP block weights are SHARED across all 27 invocations, each
invocation adding its own low-rank (LoRA, r=128) adapter on the qkv/mlp
projections, following the Zamba2 design. Mamba2: d_inner=2*d_model=7168,
112 heads x 64 head_dim, state 64, conv kernel 4.
Runs long_500k: hybrid family (SSM state O(1); shared-attn KV grows but is
sequence-sharded).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    pattern=("mamba", "mamba", "shared_attn"),
    train_accum=4,
    mlp_type="swiglu",
    ssm_state=64,
    ssm_heads=112,
    ssm_head_dim=64,
    d_inner=7168,
    conv_kernel=4,
    chunk_size=32,
    shared_lora_rank=128,
)

"""Grok-1-314B — MoE, 8 experts top-2.

[hf:xai-org/grok-1; unverified]. 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, head_dim 128, gated-GELU experts, attention logit softcap 30
(grok uses a tanh attn-logit clamp), embeddings scaled.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    pattern=("moe",),
    attn_softcap=30.0,
    num_experts=8,
    experts_per_tok=2,
    moe_d_ff=32768,
    train_accum=16,
    bf16_moments=True,
    mlp_type="geglu",
    embed_scale=True,
    tie_embeddings=True,
)

"""RWKV6-7B (Finch) — attention-free, data-dependent per-channel decay.

[arXiv:2404.05892; hf]. 32L d_model=4096 d_ff=14336 vocab=65536. WKV heads:
64 heads x 64 head_dim; token-shift mixing; channel-mix FFN (relu^2).
Runs long_500k: state is O(1) in sequence length.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=14336,
    vocab_size=65536,
    pattern=("rwkv",),
    attn_type="none",
    train_accum=4,
    mlp_type="rwkv_cmix",
    ssm_heads=64,
    ssm_head_dim=64,
    chunk_size=32,
)

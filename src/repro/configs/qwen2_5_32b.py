"""Qwen2.5-32B — dense, GQA with QKV bias.

[hf:Qwen/Qwen2.5 family; hf]. 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064, head_dim 128, rope theta 1e6.

Note: 40 heads do not divide the 16-way model axis; the sharding resolver
replicates the head dim for attention weights (FFN stays 16-way TP) — see
runtime/sharding.py and the §Perf head-padding discussion.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    pattern=("global",),
    train_accum=16,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
)

"""Gemma3-12B — 5:1 local:global attention, 128k context, qk_norm.

[hf:google/gemma-3 family; unverified]. 48L d_model=3840 16H (GQA kv=8)
d_ff=15360 vocab=262144. head_dim 256, sliding window 1024 on local layers,
global layers rope theta 1e6 (local 1e4), GeGLU, tied scaled embeddings,
no softcap (replaced by qk_norm in gemma3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    pattern=("local", "local", "local", "local", "local", "global"),
    train_accum=8,
    mlp_type="geglu",
    qk_norm=True,
    sliding_window=1024,
    rope_theta=1e6,
    local_rope_theta=1e4,
    tie_embeddings=True,
    embed_scale=True,
    sandwich_norm=True,
)

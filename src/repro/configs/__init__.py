"""Config registry: ``get_config(arch_id)`` + the assigned shape grid."""
from repro.configs.base import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    InputShape,
    ModelConfig,
)

from repro.configs.internvl2_2b import CONFIG as _internvl2_2b
from repro.configs.musicgen_large import CONFIG as _musicgen_large
from repro.configs.qwen3_1_7b import CONFIG as _qwen3_1_7b
from repro.configs.qwen2_5_32b import CONFIG as _qwen2_5_32b
from repro.configs.gemma2_27b import CONFIG as _gemma2_27b
from repro.configs.gemma3_12b import CONFIG as _gemma3_12b
from repro.configs.rwkv6_7b import CONFIG as _rwkv6_7b
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek_v2_236b
from repro.configs.grok_1_314b import CONFIG as _grok_1_314b
from repro.configs.zamba2_7b import CONFIG as _zamba2_7b

CONFIGS = {
    c.name: c
    for c in (
        _internvl2_2b,
        _musicgen_large,
        _qwen3_1_7b,
        _qwen2_5_32b,
        _gemma2_27b,
        _gemma3_12b,
        _rwkv6_7b,
        _deepseek_v2_236b,
        _grok_1_314b,
        _zamba2_7b,
    )
}

ARCH_IDS = tuple(CONFIGS)


def get_config(arch: str) -> ModelConfig:
    if arch not in CONFIGS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(CONFIGS)}")
    return CONFIGS[arch]


def cells():
    """All assigned (arch, shape) cells, with applicability flag."""
    for arch, cfg in CONFIGS.items():
        for shape in SHAPES.values():
            yield arch, shape, cfg.shape_applicable(shape)


__all__ = [
    "CONFIGS", "ARCH_IDS", "get_config", "cells", "ModelConfig", "InputShape",
    "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]

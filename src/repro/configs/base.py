"""ModelConfig: one dataclass describing every architecture in the zoo.

A config is *data only* — `models.zoo.build_model(config)` turns it into
(param tree, apply fns). Reduced smoke variants come from `config.reduced()`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) cell + which step it lowers."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer stacking: `pattern` repeats num_layers/len(pattern) times and is
    # lax.scan-ed; the first `first_dense_layers` are unscanned dense layers
    # (DeepSeek-V2 keeps layer 0 dense).
    pattern: Tuple[str, ...] = ("global",)   # global|local|moe|rwkv|mamba|shared_attn
    first_dense_layers: int = 0

    # attention flavor
    attn_type: str = "gqa"         # gqa | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 1e4
    local_rope_theta: Optional[float] = None
    sliding_window: Optional[int] = None
    pos_embedding: str = "rope"    # rope | sinusoidal

    # MLP flavor
    mlp_type: str = "swiglu"       # swiglu | geglu | gelu | rwkv_cmix
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma multiplies embeddings by sqrt(d)
    sandwich_norm: bool = False    # gemma2/3 pre+post block norms

    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    moe_backend: str = "einsum"    # einsum | ragged (dispatch implementation)

    # MLA (DeepSeek-V2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False       # absorbed-projection decode (optimized)

    # SSM / RWKV
    ssm_state: int = 0
    ssm_heads: int = 0             # wkv / mamba heads
    ssm_head_dim: int = 0
    d_inner: int = 0               # mamba expand dim
    conv_kernel: int = 4
    chunk_size: int = 32           # chunked-scan block length
    shared_lora_rank: int = 0      # zamba per-invocation LoRA on shared block

    # modality frontend STUB (assignment: precomputed embeddings)
    frontend: Optional[str] = None  # vit_stub | cond_stub
    frontend_tokens: int = 0

    # serving-time cache layout: paged_kv=True keeps EVERY attention layer's
    # cache dense and token-indexed (row r == token r, sliding windows become
    # an explicit decode-time mask instead of a ring). This is the layout the
    # paged-KV serving engine requires: pages map 1:1 onto token ranges for
    # every layer, so prefix pages are shareable across requests and a page
    # pool can evict/restore any range. Training/prefill math is unchanged.
    paged_kv: bool = False

    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    # training-time knobs
    remat: bool = True
    vocab_chunk: int = 16384       # chunked cross-entropy tile (PUL-style)
    train_accum: int = 8           # gradient-accumulation microbatches
    seq_shard_carry: bool = False  # remat-saved group carries sharded over
                                   # the model axis on the seq dim (REFUTED
                                   # on XLA SPMD — kept for the §Perf log)
    bf16_moments: bool = False     # Adam m/v in bf16 (giants: 6 B/param
                                   # saved; fp32 math inside the update)

    def __post_init__(self):
        scanned = self.num_layers - self.first_dense_layers
        if scanned % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: {scanned} scanned layers not divisible by "
                f"pattern {self.pattern}"
            )

    @property
    def padded_vocab(self) -> int:
        """Embedding/lm_head rows padded to a vocab_chunk multiple: shards
        cleanly over the model axis and removes the runtime pad+reshape in
        the chunked loss. Pad rows are never gathered (token ids < vocab)
        and are masked out of the loss/logits."""
        return -(-self.vocab_size // self.vocab_chunk) * self.vocab_chunk

    @property
    def num_groups(self) -> int:
        return (self.num_layers - self.first_dense_layers) // len(self.pattern)

    @property
    def supports_long_context(self) -> bool:
        """True if decode at 500k tokens is sub-quadratic / bounded-window.

        SSM & hybrid have O(1) state; gemma's sliding-window local layers
        bound the KV working set (global layers decode in O(S) per token).
        Pure full-attention archs skip long_500k (see DESIGN.md §5).
        """
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def shape_applicable(self, shape: InputShape) -> bool:
        if shape.name == "long_500k":
            return self.supports_long_context
        return True

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        scale = {}
        pat = len(self.pattern)
        scale["num_layers"] = self.first_dense_layers + max(1, 2 // pat) * pat
        scale["d_model"] = 64
        scale["num_heads"] = 4
        scale["num_kv_heads"] = min(self.num_kv_heads, 2) or 2
        if self.num_kv_heads == self.num_heads:
            scale["num_kv_heads"] = 4
        scale["head_dim"] = 16
        scale["d_ff"] = 128
        scale["vocab_size"] = 256
        scale["sliding_window"] = min(self.sliding_window, 16) if self.sliding_window else None
        if self.num_experts:
            scale["num_experts"] = min(self.num_experts, 8)
            scale["experts_per_tok"] = min(self.experts_per_tok, 2)
            scale["moe_d_ff"] = 32
        if self.q_lora_rank:
            scale["q_lora_rank"] = 32
        if self.kv_lora_rank:
            scale.update(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                         v_head_dim=16, head_dim=24)
        if self.ssm_heads:
            scale.update(ssm_heads=4, ssm_head_dim=16, ssm_state=16,
                         d_inner=128, chunk_size=8)
        if self.shared_lora_rank:
            scale["shared_lora_rank"] = 8
        if self.frontend_tokens:
            scale["frontend_tokens"] = 4
        scale["vocab_chunk"] = 64
        return dataclasses.replace(self, **scale)

"""DeepSeek-V2-236B — MLA (kv_lora=512) + MoE (2 shared + 160 routed, top-6).

[arXiv:2405.04434; hf]. 60L d_model=5120 128H d_ff(expert)=1536 vocab=102400.
MLA: q_lora 1536, kv_lora 512, qk_nope 128 + qk_rope 64, v_head 128.
Layer 0 is dense (first_dense_layers=1), remaining 59 are MoE.
`mla_absorb` enables the absorbed-projection decode path (§Perf).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,                  # qk_nope (128) + qk_rope (64)
    d_ff=12288,                    # dense layer-0 FFN (DeepSeek-V2 inter size)
    vocab_size=102400,
    pattern=("moe",),
    first_dense_layers=1,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=160,
    experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    train_accum=16,
    bf16_moments=True,
    mlp_type="swiglu",
    moe_backend="gather",   # sort-based dispatch; einsum backend costs ~2x FLOPs at E=160 (see EXPERIMENTS.md §Perf B)
)

"""Qwen3-1.7B — dense, GQA, per-head RMS qk_norm, no qkv bias.

[hf:Qwen/Qwen3-8B family; hf]. 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, head_dim 128, rope theta 1e6, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    pattern=("global",),
    train_accum=2,
    mlp_type="swiglu",
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
)

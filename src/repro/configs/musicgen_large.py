"""MusicGen-large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]. 48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192
vocab=2048 (EnCodec codebook). The audio/conditioning frontend is a STUB:
`input_specs()` provides precomputed conditioning embeddings (B, 64, d_model)
prepended to the token stream; the backbone is a vanilla post-Moore-friendly
transformer with sinusoidal positions and non-gated GELU MLP (4x widening),
matching the audiocraft implementation.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    pattern=("global",),
    train_accum=4,
    mlp_type="gelu",
    pos_embedding="sinusoidal",
    frontend="cond_stub",
    frontend_tokens=64,
)

"""The paper's SUM microbenchmark as a PUL Pallas kernel (Exps. 1, 3, 4).

Trace-driven random row aggregation: rows of an HBM-resident table are
requested in trace order through a distance-d preload pipeline into VMEM ring
slots, and reduced while later requests are in flight — Listing 1 verbatim,
with the trace playing the paper's pre-generated random access pattern.

Knobs swept by benchmarks: preload distance (Exp. 3), rows-per-request =
transfer size (Exp. 4), BATCH vs SEQUENTIAL issue (Fig. 5-D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import PULConfig, PreloadStream, pul_loop, ring_scratch


def _kernel(trace_smem, data_hbm, out_smem, buf, sems, *, cfg: PULConfig,
            n_req: int, rows_per_req: int):
    stream = PreloadStream(
        data_hbm, buf, sems,
        # the paper's byte-addressable "arbitrary address" preload: the row
        # index for request i comes from the trace (SMEM scalar read)
        index_map=lambda i: (trace_smem[i] * rows_per_req, 0),
        cfg=cfg, n_blocks=n_req)

    def body(i, views, acc):
        blk = views[0][...]                       # (rows_per_req, W)
        return acc + jnp.sum(blk.astype(jnp.float32))

    acc = pul_loop(n_req, [stream], body, jnp.float32(0.0), cfg)
    out_smem[0] = acc


def pul_sum(data: jax.Array, trace: jax.Array, *, cfg: PULConfig = PULConfig(),
            rows_per_req: int = 1, interpret: bool = True) -> jax.Array:
    """sum over data[trace[i]*rows_per_req : +rows_per_req] for all i.

    data: (R, W) float; trace: (n_req,) int32 of block indices.
    """
    n_req = trace.shape[0]
    W = data.shape[1]
    block = (rows_per_req, W)
    kern = functools.partial(_kernel, cfg=cfg, n_req=n_req,
                             rows_per_req=rows_per_req)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        scratch_shapes=list(ring_scratch(cfg, block, data.dtype)),
        interpret=interpret,
    )(trace, data)
    return out[0]

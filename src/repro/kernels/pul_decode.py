"""Decode attention: one query token vs a long KV cache, PUL-streamed.

The serving-side twin of pul_attention and the purest LM instance of the
paper's setting: a tiny amount of compute (one token's scores) against a
huge slow-memory operand (the KV cache), i.e. minimal operational intensity.
Each grid step handles one (batch, kv-head) pair; the cache streams through
a distance-d preload ring while the VPU reduces the previous block's online
softmax. All GQA query heads of the kv group ride the same stream (the
transfer is amortized over G heads — PUL's configurable transfer size).

Layout: q (B, H, hd); k/v caches (B, K, S, hd); `length` masks valid cache
entries (<= S), so ring/paged caches pass their fill level.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import PULConfig, PreloadStream, pul_loop, ring_scratch

NEG_INF = -2.0e38


def _kernel(len_smem, q_vmem, k_hbm, v_hbm, o_vmem, kbuf, ksems, vbuf, vsems,
            *, cfg: PULConfig, bs: int, ns: int, S: int, group: int,
            scale: float, softcap: Optional[float]):
    b = pl.program_id(0)
    kv_h = pl.program_id(1)
    length = len_smem[b]

    k_st = PreloadStream(k_hbm, kbuf, ksems,
                         index_map=lambda t: (b, kv_h, t * bs, 0),
                         cfg=cfg, n_blocks=ns)
    v_st = PreloadStream(v_hbm, vbuf, vsems,
                         index_map=lambda t: (b, kv_h, t * bs, 0),
                         cfg=cfg, n_blocks=ns)

    q = q_vmem[0, 0].astype(jnp.float32)                # (G, hd)

    def body(t, views, carry):
        m, l, acc = carry                               # (G,1),(G,1),(G,hd)
        kt = views[0][0, 0].astype(jnp.float32)         # (bs, hd)
        vt = views[1][0, 0].astype(jnp.float32)
        logits = jnp.dot(q, kt.T, preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        jk = t * bs + jax.lax.iota(jnp.int32, bs)
        logits = jnp.where((jk < length)[None, :], logits, NEG_INF)  # (G,bs)
        bmax = jnp.max(logits, axis=-1, keepdims=True)
        new_m = jnp.maximum(m, bmax)
        corr = jnp.exp(m - new_m)
        p = jnp.exp(logits - new_m)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.dot(p, vt, preferred_element_type=jnp.float32)
        return new_m, l, acc

    G, hd = q.shape
    init = (jnp.full((G, 1), NEG_INF, jnp.float32),
            jnp.zeros((G, 1), jnp.float32),
            jnp.zeros((G, hd), jnp.float32))
    m, l, acc = pul_loop(ns, [k_st, v_st], body, init, cfg)
    o_vmem[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_vmem.dtype)


def pul_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         length, *, cfg: PULConfig = PULConfig(),
                         bs: int = 128, scale: Optional[float] = None,
                         softcap: Optional[float] = None,
                         interpret: bool = True) -> jax.Array:
    """q: (B,H,hd); k,v: (B,K,S,hd); length: (B,) valid cache entries.
    Returns (B,H,hd)."""
    B, H, hd = q.shape
    _, K, S, _ = k.shape
    assert H % K == 0
    G = H // K
    bs = min(bs, S)
    ns = -(-S // bs)
    if ns * bs != S:
        pad = ((0, 0), (0, 0), (0, ns * bs - S), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    length = jnp.asarray(length, jnp.int32).reshape(B)
    # group query heads by kv head: (B, K, G, hd)
    qg = q.reshape(B, K, G, hd)
    kern = functools.partial(_kernel, cfg=cfg, bs=bs, ns=ns, S=S, group=G,
                             scale=scale, softcap=softcap)
    out = pl.pallas_call(
        kern,
        grid=(B, K),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
        scratch_shapes=[
            *ring_scratch(cfg, (1, 1, bs, hd), k.dtype),
            *ring_scratch(cfg, (1, 1, bs, hd), v.dtype),
        ],
        interpret=interpret,
    )(length, qg, k, v)
    return out.reshape(B, H, hd)

"""PUL Pallas kernels: the paper's technique at TPU compute hot-spots.

Each kernel pairs with a pure-jnp oracle in ref.py; ops.py exposes jit'd
wrappers that interpret on CPU and lower to Mosaic on TPU.
"""
from repro.kernels import ref
from repro.kernels.ops import (
    attention_op,
    filter_op,
    gather_op,
    matmul_op,
    sum_op,
)
from repro.kernels.pul_sum import pul_sum
from repro.kernels.pul_gather import pul_gather, pul_page_gather
from repro.kernels.pul_matmul import pul_matmul
from repro.kernels.pul_attention import (
    pul_attention,
    pul_paged_decode_attention,
    pul_paged_mla_decode_attention,
    pul_paged_sweep_decode_attention,
    pul_paged_sweep_mla_decode_attention,
)
from repro.kernels.pul_filter import pul_filter
from repro.kernels.pul_decode import pul_decode_attention

__all__ = ["ref", "sum_op", "gather_op", "matmul_op", "attention_op",
           "filter_op", "pul_sum", "pul_gather", "pul_page_gather",
           "pul_matmul", "pul_attention", "pul_filter",
           "pul_decode_attention", "pul_paged_decode_attention",
           "pul_paged_mla_decode_attention",
           "pul_paged_sweep_decode_attention",
           "pul_paged_sweep_mla_decode_attention"]

"""Random row gather with preload + unload (embedding / KV-block fetch).

out[i] = table[trace[i]] — the data path of an embedding lookup or a paged
KV-cache fetch. Reads ride a distance-d preload ring; writes leave through an
unload ring (paper §2: preloading and unloading are independent FIFOs and
synchronize independently).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import PULConfig, PreloadStream, UnloadStream, pul_loop, ring_scratch


def _kernel(trace_smem, table_hbm, out_hbm, pbuf, psems, ubuf, usems, *,
            cfg: PULConfig, n_req: int, rows_per_req: int):
    pre = PreloadStream(
        table_hbm, pbuf, psems,
        index_map=lambda i: (trace_smem[i] * rows_per_req, 0),
        cfg=cfg, n_blocks=n_req)
    unl = UnloadStream(
        out_hbm, ubuf, usems,
        index_map=lambda i: (i * rows_per_req, 0),
        cfg=cfg, n_blocks=n_req)

    def body(i, views, carry):
        slot = unl.slot(i)
        slot[...] = views[0][...]
        unl.issue(i)
        return carry

    pul_loop(n_req, [pre], body, 0, cfg, unloads=[unl])


def pul_page_gather(store: jax.Array, page_table: jax.Array, *,
                    cfg: PULConfig = PULConfig(),
                    interpret: bool = True) -> jax.Array:
    """Assemble sequences from a paged KV store (the serving gather path).

    store: (n_pages, page_tokens, feat) physical page frames.
    page_table: (n_seqs, pages_per_seq) int32 page ids (a serving slot's
      logical->physical page map; the SMEM-resident trace of the PUL gather).
    Returns (n_seqs, pages_per_seq * page_tokens, feat): each sequence's
    token-contiguous KV, pulled page-by-page through the preload ring and
    written back out through the unload ring.
    """
    n_pages, P, F = store.shape
    n_seqs, ppseq = page_table.shape
    flat = pul_gather(store.reshape(n_pages * P, F),
                      page_table.reshape(-1).astype(jnp.int32),
                      cfg=cfg, rows_per_req=P, interpret=interpret)
    return flat.reshape(n_seqs, ppseq * P, F)


def pul_gather(table: jax.Array, trace: jax.Array, *,
               cfg: PULConfig = PULConfig(), rows_per_req: int = 1,
               interpret: bool = True) -> jax.Array:
    n_req = trace.shape[0]
    W = table.shape[1]
    block = (rows_per_req, W)
    kern = functools.partial(_kernel, cfg=cfg, n_req=n_req,
                             rows_per_req=rows_per_req)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n_req * rows_per_req, W), table.dtype),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[*ring_scratch(cfg, block, table.dtype),
                        *ring_scratch(cfg, block, table.dtype)],
        interpret=interpret,
    )(trace, table)

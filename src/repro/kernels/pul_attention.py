"""Flash attention with PUL-streamed KV (causal, GQA, window, softcap).

The TPU-native adaptation of the paper's idea applied to the dominant
memory-bound op of LM serving/training: query tiles live in VMEM (delivered
by the standard Pallas pipeline), while the long KV stream — the paper's
"dataset in slow memory" — is pulled through a distance-d preload ring with
online-softmax compute interleaved against in-flight DMAs. Sliding-window
layers simply bound the streamed range (gemma2/3).

Layout: q (B, H, T, hd); k/v (B, K, S, hd); GQA mapping h -> h // (H/K) is
done by the kv index_map inside the kernel (no host-side repeat).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import PULConfig, PreloadStream, pul_loop, ring_scratch

NEG_INF = -2.0e38


def _kernel(q_vmem, k_hbm, v_hbm, o_vmem, kbuf, ksems, vbuf, vsems,
            m_scr, l_scr, acc_scr, *, cfg: PULConfig, bt: int, bs: int,
            ns: int, S: int, T: int, group: int, scale: float,
            softcap: Optional[float], window: Optional[int], causal: bool):
    b = pl.program_id(0)
    h = pl.program_id(1)
    tq = pl.program_id(2)
    kv_h = h // group

    k_st = PreloadStream(k_hbm, kbuf, ksems,
                         index_map=lambda t: (b, kv_h, t * bs, 0),
                         cfg=cfg, n_blocks=ns)
    v_st = PreloadStream(v_hbm, vbuf, vsems,
                         index_map=lambda t: (b, kv_h, t * bs, 0),
                         cfg=cfg, n_blocks=ns)

    m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
    l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
    acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_vmem[0, 0].astype(jnp.float32)                 # (bt, hd)
    # absolute query positions (queries end-aligned with keys: offset S - T)
    iq = tq * bt + jax.lax.iota(jnp.int32, bt) + (S - T)

    def body(t, views, carry):
        kt = views[0][0, 0].astype(jnp.float32)          # (bs, hd)
        vt = views[1][0, 0].astype(jnp.float32)
        logits = jnp.dot(q, kt.T, preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        jk = t * bs + jax.lax.iota(jnp.int32, bs)
        msk = jk[None, :] < S
        if causal:
            msk &= jk[None, :] <= iq[:, None]
        if window is not None:
            msk &= jk[None, :] > iq[:, None] - window
        logits = jnp.where(msk, logits, NEG_INF)
        bmax = jnp.max(logits, axis=-1, keepdims=True)   # (bt,1)
        new_m = jnp.maximum(m_scr[...], bmax)
        corr = jnp.exp(m_scr[...] - new_m)
        p = jnp.exp(logits - new_m)
        m_scr[...] = new_m
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p, vt, preferred_element_type=jnp.float32)
        return carry

    pul_loop(ns, [k_st, v_st], body, 0, cfg)
    out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
    o_vmem[0, 0] = out.astype(o_vmem.dtype)


def _paged_decode_kernel(pt_smem, len_smem, q_vmem, *rest, cfg: PULConfig,
                         P: int, n_pages: int, scale: float,
                         softcap: Optional[float], window: Optional[int],
                         has_new: bool):
    if has_new:
        knew_vmem, vnew_vmem, k_hbm, v_hbm, o_vmem, \
            kbuf, ksems, vbuf, vsems = rest
    else:
        k_hbm, v_hbm, o_vmem, kbuf, ksems, vbuf, vsems = rest
    b = pl.program_id(0)
    kv_h = pl.program_id(1)
    length = len_smem[b]

    # the page table IS the preload trace: block t of the stream is whatever
    # physical page the slot's logical page t maps to (random access in slow
    # memory, sequential consumption in the ring — the paper's Exp. 2 trace)
    k_st = PreloadStream(k_hbm, kbuf, ksems,
                         index_map=lambda t: (pt_smem[b, t], kv_h, 0, 0),
                         cfg=cfg, n_blocks=n_pages)
    v_st = PreloadStream(v_hbm, vbuf, vsems,
                         index_map=lambda t: (pt_smem[b, t], kv_h, 0, 0),
                         cfg=cfg, n_blocks=n_pages)

    q = q_vmem[0, 0].astype(jnp.float32)                 # (G, hd)

    def _cap(logits):
        if softcap is not None:
            return softcap * jnp.tanh(logits / softcap)
        return logits

    def body(t, views, carry):
        m, l, acc = carry
        kt = views[0][0, 0].astype(jnp.float32)          # (P, hd)
        vt = views[1][0, 0].astype(jnp.float32)
        logits = _cap(
            jnp.dot(q, kt.T, preferred_element_type=jnp.float32) * scale)
        jk = t * P + jax.lax.iota(jnp.int32, P)
        msk = jk < length
        if window is not None:
            # the incoming query sits at absolute position `length`; cached
            # token jk is visible iff jk > length - window
            msk &= jk > length - window
        logits = jnp.where(msk[None, :], logits, NEG_INF)
        bmax = jnp.max(logits, axis=-1, keepdims=True)
        new_m = jnp.maximum(m, bmax)
        corr = jnp.exp(m - new_m)
        p = jnp.exp(logits - new_m)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.dot(p, vt, preferred_element_type=jnp.float32)
        return new_m, l, acc

    G, hd = q.shape
    init = (jnp.full((G, 1), NEG_INF, jnp.float32),
            jnp.zeros((G, 1), jnp.float32),
            jnp.zeros((G, hd), jnp.float32))
    m, l, acc = pul_loop(n_pages, [k_st, v_st], body, init, cfg)
    if has_new:
        # fold in the current token's K/V (not yet written to any page);
        # it is always causally visible and always inside the window
        kn = knew_vmem[0, 0].astype(jnp.float32)         # (1, hd)
        vn = vnew_vmem[0, 0].astype(jnp.float32)
        ls = _cap(jnp.dot(q, kn.T, preferred_element_type=jnp.float32)
                  * scale)                               # (G, 1)
        new_m = jnp.maximum(m, ls)
        corr = jnp.exp(m - new_m)
        p = jnp.exp(ls - new_m)
        l = l * corr + p
        acc = acc * corr + jnp.dot(p, vn, preferred_element_type=jnp.float32)
    o_vmem[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_vmem.dtype)


def pul_paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, page_tables: jax.Array,
                               lengths, *, cfg: PULConfig = PULConfig(),
                               scale: Optional[float] = None,
                               softcap: Optional[float] = None,
                               window: Optional[int] = None,
                               k_new: Optional[jax.Array] = None,
                               v_new: Optional[jax.Array] = None,
                               interpret: bool = True) -> jax.Array:
    """Decode attention straight over a paged KV store (serving hot path).

    q: (B, H, hd) one query token per slot; k_pages/v_pages: (NP, K, P, hd)
    physical page frames (P tokens per page); page_tables: (B, n_pages)
    int32 physical page id of each slot's logical page; lengths: (B,) valid
    tokens per slot. Returns (B, H, hd).

    `window` bounds the visible range to the last `window` tokens relative to
    the incoming query at position `lengths[b]` (sliding-window layers).
    `k_new`/`v_new` ((B, K, hd)) carry the CURRENT token's K/V — not yet
    written to any page — and are folded into the online softmax after the
    page stream, so the engine can run attention before the page write-back.

    The kernel never materializes a contiguous KV view: pages stream from
    slow memory through a distance-d preload ring, addressed by the SMEM
    page table — software paging *is* the trace-driven preload of the paper.
    """
    B, H, hd = q.shape
    NP, K, P, _ = k_pages.shape
    _, n_pages = page_tables.shape
    assert H % K == 0
    G = H // K
    has_new = k_new is not None
    assert (v_new is not None) == has_new, "k_new/v_new come as a pair"
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(B)
    qg = q.reshape(B, K, G, hd)
    kern = functools.partial(_paged_decode_kernel, cfg=cfg, P=P,
                             n_pages=n_pages, scale=scale, softcap=softcap,
                             window=window, has_new=has_new)
    new_specs, new_args = [], []
    if has_new:
        new_specs = [pl.BlockSpec((1, 1, 1, hd), lambda b, h: (b, h, 0, 0)),
                     pl.BlockSpec((1, 1, 1, hd), lambda b, h: (b, h, 0, 0))]
        new_args = [k_new.reshape(B, K, 1, hd), v_new.reshape(B, K, 1, hd)]
    out = pl.pallas_call(
        kern,
        grid=(B, K),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
            *new_specs,
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
        scratch_shapes=[
            *ring_scratch(cfg, (1, 1, P, hd), k_pages.dtype),
            *ring_scratch(cfg, (1, 1, P, hd), v_pages.dtype),
        ],
        interpret=interpret,
    )(page_tables.astype(jnp.int32), lengths, qg, *new_args,
      k_pages, v_pages)
    return out.reshape(B, H, hd)


def _paged_mla_decode_kernel(pt_smem, len_smem, qa_vmem, qr_vmem, cnew_vmem,
                             rnew_vmem, ckv_hbm, kr_hbm, o_vmem,
                             cbuf, csems, rbuf, rsems, *, cfg: PULConfig,
                             P: int, n_pages: int, scale: float):
    b = pl.program_id(0)
    length = len_smem[b]

    c_st = PreloadStream(ckv_hbm, cbuf, csems,
                         index_map=lambda t: (pt_smem[b, t], 0, 0),
                         cfg=cfg, n_blocks=n_pages)
    r_st = PreloadStream(kr_hbm, rbuf, rsems,
                         index_map=lambda t: (pt_smem[b, t], 0, 0),
                         cfg=cfg, n_blocks=n_pages)

    qa = qa_vmem[0].astype(jnp.float32)                  # (H, kvr)
    qr = qr_vmem[0].astype(jnp.float32)                  # (H, dr)

    def body(t, views, carry):
        m, l, acc = carry
        ct = views[0][0].astype(jnp.float32)             # (P, kvr)
        rt = views[1][0].astype(jnp.float32)             # (P, dr)
        logits = (jnp.dot(qa, ct.T, preferred_element_type=jnp.float32)
                  + jnp.dot(qr, rt.T, preferred_element_type=jnp.float32)
                  ) * scale                              # (H, P)
        jk = t * P + jax.lax.iota(jnp.int32, P)
        logits = jnp.where((jk < length)[None, :], logits, NEG_INF)
        bmax = jnp.max(logits, axis=-1, keepdims=True)
        new_m = jnp.maximum(m, bmax)
        corr = jnp.exp(m - new_m)
        p = jnp.exp(logits - new_m)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        # MLA: the compressed cache IS the value stream (absorbed decode)
        acc = acc * corr + jnp.dot(p, ct, preferred_element_type=jnp.float32)
        return new_m, l, acc

    H, kvr = qa.shape
    init = (jnp.full((H, 1), NEG_INF, jnp.float32),
            jnp.zeros((H, 1), jnp.float32),
            jnp.zeros((H, kvr), jnp.float32))
    m, l, acc = pul_loop(n_pages, [c_st, r_st], body, init, cfg)
    # current token's compressed KV, not yet paged
    cn = cnew_vmem[0].astype(jnp.float32)                # (1, kvr)
    rn = rnew_vmem[0].astype(jnp.float32)                # (1, dr)
    ls = (jnp.dot(qa, cn.T, preferred_element_type=jnp.float32)
          + jnp.dot(qr, rn.T, preferred_element_type=jnp.float32)) * scale
    new_m = jnp.maximum(m, ls)
    corr = jnp.exp(m - new_m)
    p = jnp.exp(ls - new_m)
    l = l * corr + p
    acc = acc * corr + jnp.dot(p, cn, preferred_element_type=jnp.float32)
    o_vmem[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_vmem.dtype)


def pul_paged_mla_decode_attention(q_abs: jax.Array, q_rope: jax.Array,
                                   ckv_pages: jax.Array, kr_pages: jax.Array,
                                   page_tables: jax.Array, lengths,
                                   c_new: jax.Array, r_new: jax.Array, *,
                                   scale: float,
                                   cfg: PULConfig = PULConfig(),
                                   interpret: bool = True) -> jax.Array:
    """Absorbed MLA decode attention straight over compressed-KV pages.

    q_abs: (B, H, kvr) queries absorbed into the compressed space; q_rope:
    (B, H, dr) rope-carrying queries; ckv_pages: (NP, P, kvr) and kr_pages:
    (NP, P, dr) physical page frames (one row per token — MLA's cache is
    head-shared); page_tables: (B, n_pages); lengths: (B,) cached tokens per
    slot; c_new/r_new: (B, kvr)/(B, dr) the current token's compressed KV.
    Returns o_c (B, H, kvr) — the caller applies the absorbed v up-projection.
    """
    B, H, kvr = q_abs.shape
    NP, P, _ = ckv_pages.shape
    dr = q_rope.shape[-1]
    _, n_pages = page_tables.shape
    lengths = jnp.asarray(lengths, jnp.int32).reshape(B)
    kern = functools.partial(_paged_mla_decode_kernel, cfg=cfg, P=P,
                             n_pages=n_pages, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B,),
        out_shape=jax.ShapeDtypeStruct((B, H, kvr), q_abs.dtype),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, H, kvr), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, H, dr), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, kvr), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, dr), lambda b: (b, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, H, kvr), lambda b: (b, 0, 0)),
        scratch_shapes=[
            *ring_scratch(cfg, (1, P, kvr), ckv_pages.dtype),
            *ring_scratch(cfg, (1, P, dr), kr_pages.dtype),
        ],
        interpret=interpret,
    )(page_tables.astype(jnp.int32), lengths, q_abs, q_rope,
      c_new.reshape(B, 1, kvr), r_new.reshape(B, 1, dr),
      ckv_pages, kr_pages)


def _paged_sweep_decode_kernel(pt_smem, len_smem, frames_smem, offs_smem,
                               layer_smem, q_vmem, knew_vmem, vnew_vmem,
                               k_hbm, v_hbm, o_vmem, kp_out, vp_out,
                               kbuf, ksems, vbuf, vsems, wsem, *,
                               cfg: PULConfig, P: int, n_pages: int,
                               scale: float, softcap: Optional[float],
                               window: Optional[int]):
    b = pl.program_id(0)
    kv_h = pl.program_id(1)
    g = layer_smem[0]
    length = len_smem[b]

    # same page-table-driven stream as the per-layer kernel, with the layer
    # scalar prepended: block t is plane row (g, pt[b, t], kv_h) — the sweep
    # reads the SAME bytes the per-layer launch would, just without the
    # host-side layer slice
    k_st = PreloadStream(k_hbm, kbuf, ksems,
                         index_map=lambda t: (g, pt_smem[b, t], kv_h, 0, 0),
                         cfg=cfg, n_blocks=n_pages)
    v_st = PreloadStream(v_hbm, vbuf, vsems,
                         index_map=lambda t: (g, pt_smem[b, t], kv_h, 0, 0),
                         cfg=cfg, n_blocks=n_pages)

    q = q_vmem[0, 0].astype(jnp.float32)                 # (G, hd)

    def _cap(logits):
        if softcap is not None:
            return softcap * jnp.tanh(logits / softcap)
        return logits

    def body(t, views, carry):
        m, l, acc = carry
        kt = views[0][0, 0, 0].astype(jnp.float32)       # (P, hd)
        vt = views[1][0, 0, 0].astype(jnp.float32)
        logits = _cap(
            jnp.dot(q, kt.T, preferred_element_type=jnp.float32) * scale)
        jk = t * P + jax.lax.iota(jnp.int32, P)
        msk = jk < length
        if window is not None:
            msk &= jk > length - window
        logits = jnp.where(msk[None, :], logits, NEG_INF)
        bmax = jnp.max(logits, axis=-1, keepdims=True)
        new_m = jnp.maximum(m, bmax)
        corr = jnp.exp(m - new_m)
        p = jnp.exp(logits - new_m)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.dot(p, vt, preferred_element_type=jnp.float32)
        return new_m, l, acc

    G, hd = q.shape
    init = (jnp.full((G, 1), NEG_INF, jnp.float32),
            jnp.zeros((G, 1), jnp.float32),
            jnp.zeros((G, hd), jnp.float32))
    m, l, acc = pul_loop(n_pages, [k_st, v_st], body, init, cfg)
    # the current token (position `length`, not yet paged) is always
    # causally visible and always inside the window
    kn = knew_vmem[0, 0, 0].astype(jnp.float32)          # (1, hd)
    vn = vnew_vmem[0, 0, 0].astype(jnp.float32)
    ls = _cap(jnp.dot(q, kn.T, preferred_element_type=jnp.float32) * scale)
    new_m = jnp.maximum(m, ls)
    corr = jnp.exp(m - new_m)
    p = jnp.exp(ls - new_m)
    l = l * corr + p
    acc = acc * corr + jnp.dot(p, vn, preferred_element_type=jnp.float32)
    o_vmem[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_vmem.dtype)

    # fused commit epilogue: write the current token's K/V row into its tail
    # page at (layer, frame, kv_h, offset). The attention stream above only
    # reads positions < length and this row IS position length, so the write
    # can never race a read of itself; inactive slots' frames point at the
    # pool's TRASH sink. The host side accounts/validates this commit via
    # KVPagePool.note_fused_commit BEFORE the launch.
    f = frames_smem[b]
    o = offs_smem[b]
    kdst = kp_out.at[pl.ds(g, 1), pl.ds(f, 1), pl.ds(kv_h, 1),
                     pl.ds(o, 1), :]
    vdst = vp_out.at[pl.ds(g, 1), pl.ds(f, 1), pl.ds(kv_h, 1),
                     pl.ds(o, 1), :]
    kcp = pltpu.make_async_copy(knew_vmem.at[...], kdst, wsem)
    kcp.start()
    kcp.wait()
    vcp = pltpu.make_async_copy(vnew_vmem.at[...], vdst, wsem)
    vcp.start()
    vcp.wait()


def pul_paged_sweep_decode_attention(
        q: jax.Array, k_planes: jax.Array, v_planes: jax.Array, layer,
        page_tables: jax.Array, lengths, k_new: jax.Array, v_new: jax.Array,
        frames, offsets, *, cfg: PULConfig = PULConfig(),
        scale: Optional[float] = None, softcap: Optional[float] = None,
        window: Optional[int] = None, interpret: bool = True):
    """One layer step of the single-sweep paged decode over per-layer planes.

    Reads layer `layer` of the full stacked planes and fuses the commit of
    the current token's K/V into the kernel epilogue — the in-kernel half of
    the `KVStoreLayout` commit contract.

    q: (B, H, hd); k_planes/v_planes: (L, NF, K, P, hd) the ENTIRE per-layer
    page store (never sliced on the host — the zero-copy point); layer: ()
    int32 scalar (prefetched to SMEM; a scan-carried layer index); k_new /
    v_new: (B, K, hd) the current token's K/V, merged into the online
    softmax AND written to plane position (layer, frames[b], kv_h,
    offsets[b]); frames/offsets: (B,) int32 tail-page frame and in-page row
    per slot (TRASH frame for inactive slots — never the zero frame).

    Returns (out (B, H, hd), k_planes, v_planes) where the plane outputs are
    input/output-aliased: XLA updates the store in place, the caller threads
    them forward (the engine donates them through the jitted step).
    """
    B, H, hd = q.shape
    L, NF, K, P, _ = k_planes.shape
    _, n_pages = page_tables.shape
    assert H % K == 0
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(B)
    layer = jnp.asarray(layer, jnp.int32).reshape(1)
    frames = jnp.asarray(frames, jnp.int32).reshape(B)
    offsets = jnp.asarray(offsets, jnp.int32).reshape(B)
    qg = q.reshape(B, K, G, hd)
    kern = functools.partial(_paged_sweep_decode_kernel, cfg=cfg, P=P,
                             n_pages=n_pages, scale=scale, softcap=softcap,
                             window=window)
    out, kp, vp = pl.pallas_call(
        kern,
        grid=(B, K),
        out_shape=[
            jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
            jax.ShapeDtypeStruct(k_planes.shape, k_planes.dtype),
            jax.ShapeDtypeStruct(v_planes.shape, v_planes.dtype),
        ],
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # page tables
            pl.BlockSpec(memory_space=pltpu.SMEM),   # lengths
            pl.BlockSpec(memory_space=pltpu.SMEM),   # commit frames
            pl.BlockSpec(memory_space=pltpu.SMEM),   # commit offsets
            pl.BlockSpec(memory_space=pltpu.SMEM),   # layer scalar
            pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
            # new-token rows, rank-matched to the plane for the epilogue DMA
            pl.BlockSpec((1, 1, 1, 1, hd), lambda b, h: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, hd), lambda b, h: (b, h, 0, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        # flattened inputs: pt, len, frames, offs, layer, q, k_new, v_new,
        # k_planes (8), v_planes (9) -> aliased to outputs 1 and 2
        input_output_aliases={8: 1, 9: 2},
        scratch_shapes=[
            *ring_scratch(cfg, (1, 1, 1, P, hd), k_planes.dtype),
            *ring_scratch(cfg, (1, 1, 1, P, hd), v_planes.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(page_tables.astype(jnp.int32), lengths, frames, offsets, layer, qg,
      k_new.astype(k_planes.dtype).reshape(B, K, 1, 1, hd),
      v_new.astype(v_planes.dtype).reshape(B, K, 1, 1, hd),
      k_planes, v_planes)
    return out.reshape(B, H, hd), kp, vp


def _paged_sweep_mla_decode_kernel(pt_smem, len_smem, frames_smem, offs_smem,
                                   layer_smem, qa_vmem, qr_vmem, cnew_vmem,
                                   rnew_vmem, ckv_hbm, kr_hbm, o_vmem,
                                   cp_out, rp_out, cbuf, csems, rbuf, rsems,
                                   wsem, *, cfg: PULConfig, P: int,
                                   n_pages: int, scale: float):
    b = pl.program_id(0)
    g = layer_smem[0]
    length = len_smem[b]

    c_st = PreloadStream(ckv_hbm, cbuf, csems,
                         index_map=lambda t: (g, pt_smem[b, t], 0, 0),
                         cfg=cfg, n_blocks=n_pages)
    r_st = PreloadStream(kr_hbm, rbuf, rsems,
                         index_map=lambda t: (g, pt_smem[b, t], 0, 0),
                         cfg=cfg, n_blocks=n_pages)

    qa = qa_vmem[0].astype(jnp.float32)                  # (H, kvr)
    qr = qr_vmem[0].astype(jnp.float32)                  # (H, dr)

    def body(t, views, carry):
        m, l, acc = carry
        ct = views[0][0, 0].astype(jnp.float32)          # (P, kvr)
        rt = views[1][0, 0].astype(jnp.float32)          # (P, dr)
        logits = (jnp.dot(qa, ct.T, preferred_element_type=jnp.float32)
                  + jnp.dot(qr, rt.T, preferred_element_type=jnp.float32)
                  ) * scale
        jk = t * P + jax.lax.iota(jnp.int32, P)
        logits = jnp.where((jk < length)[None, :], logits, NEG_INF)
        bmax = jnp.max(logits, axis=-1, keepdims=True)
        new_m = jnp.maximum(m, bmax)
        corr = jnp.exp(m - new_m)
        p = jnp.exp(logits - new_m)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.dot(p, ct, preferred_element_type=jnp.float32)
        return new_m, l, acc

    H, kvr = qa.shape
    init = (jnp.full((H, 1), NEG_INF, jnp.float32),
            jnp.zeros((H, 1), jnp.float32),
            jnp.zeros((H, kvr), jnp.float32))
    m, l, acc = pul_loop(n_pages, [c_st, r_st], body, init, cfg)
    cn = cnew_vmem[0, 0].astype(jnp.float32)             # (1, kvr)
    rn = rnew_vmem[0, 0].astype(jnp.float32)             # (1, dr)
    ls = (jnp.dot(qa, cn.T, preferred_element_type=jnp.float32)
          + jnp.dot(qr, rn.T, preferred_element_type=jnp.float32)) * scale
    new_m = jnp.maximum(m, ls)
    corr = jnp.exp(m - new_m)
    p = jnp.exp(ls - new_m)
    l = l * corr + p
    acc = acc * corr + jnp.dot(p, cn, preferred_element_type=jnp.float32)
    o_vmem[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_vmem.dtype)

    # fused commit epilogue (see _paged_sweep_decode_kernel): the current
    # token's compressed KV lands at (layer, frame, offset) of both planes
    f = frames_smem[b]
    o = offs_smem[b]
    cdst = cp_out.at[pl.ds(g, 1), pl.ds(f, 1), pl.ds(o, 1), :]
    rdst = rp_out.at[pl.ds(g, 1), pl.ds(f, 1), pl.ds(o, 1), :]
    ccp = pltpu.make_async_copy(cnew_vmem.at[...], cdst, wsem)
    ccp.start()
    ccp.wait()
    rcp = pltpu.make_async_copy(rnew_vmem.at[...], rdst, wsem)
    rcp.start()
    rcp.wait()


def pul_paged_sweep_mla_decode_attention(
        q_abs: jax.Array, q_rope: jax.Array, ckv_planes: jax.Array,
        kr_planes: jax.Array, layer, page_tables: jax.Array, lengths,
        c_new: jax.Array, r_new: jax.Array, frames, offsets, *, scale: float,
        cfg: PULConfig = PULConfig(), interpret: bool = True):
    """Absorbed-MLA layer step of the single-sweep paged decode.

    ckv_planes: (L, NF, P, kvr), kr_planes: (L, NF, P, dr) — the entire
    per-layer compressed page store; `layer` selects the plane row in-kernel
    via the prefetched SMEM scalar. c_new/r_new ((B, kvr)/(B, dr)) are merged
    into the online softmax AND committed to (layer, frames[b], offsets[b])
    in the fused epilogue. Returns (o_c (B, H, kvr), ckv_planes, kr_planes)
    with the planes input/output-aliased for in-place update.
    """
    B, H, kvr = q_abs.shape
    L, NF, P, _ = ckv_planes.shape
    dr = q_rope.shape[-1]
    _, n_pages = page_tables.shape
    lengths = jnp.asarray(lengths, jnp.int32).reshape(B)
    layer = jnp.asarray(layer, jnp.int32).reshape(1)
    frames = jnp.asarray(frames, jnp.int32).reshape(B)
    offsets = jnp.asarray(offsets, jnp.int32).reshape(B)
    kern = functools.partial(_paged_sweep_mla_decode_kernel, cfg=cfg, P=P,
                             n_pages=n_pages, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B,),
        out_shape=[
            jax.ShapeDtypeStruct((B, H, kvr), q_abs.dtype),
            jax.ShapeDtypeStruct(ckv_planes.shape, ckv_planes.dtype),
            jax.ShapeDtypeStruct(kr_planes.shape, kr_planes.dtype),
        ],
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, H, kvr), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, H, dr), lambda b: (b, 0, 0)),
            # new-token rows, rank-matched to the planes for the epilogue DMA
            pl.BlockSpec((1, 1, 1, kvr), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1, 1, dr), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, H, kvr), lambda b: (b, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        # flattened inputs: pt, len, frames, offs, layer, qa, qr, c_new,
        # r_new, ckv_planes (9), kr_planes (10) -> aliased to outputs 1, 2
        input_output_aliases={9: 1, 10: 2},
        scratch_shapes=[
            *ring_scratch(cfg, (1, 1, P, kvr), ckv_planes.dtype),
            *ring_scratch(cfg, (1, 1, P, dr), kr_planes.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(page_tables.astype(jnp.int32), lengths, frames, offsets, layer,
      q_abs, q_rope,
      c_new.astype(ckv_planes.dtype).reshape(B, 1, 1, kvr),
      r_new.astype(kr_planes.dtype).reshape(B, 1, 1, dr),
      ckv_planes, kr_planes)


def pul_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  cfg: PULConfig = PULConfig(), bt: int = 128, bs: int = 128,
                  causal: bool = True, scale: Optional[float] = None,
                  softcap: Optional[float] = None,
                  window: Optional[int] = None,
                  interpret: bool = True) -> jax.Array:
    B, H, T, hd = q.shape
    _, K, S, _ = k.shape
    assert H % K == 0
    bt = min(bt, T)
    bs = min(bs, S)
    assert T % bt == 0
    ns = -(-S // bs)
    if ns * bs != S:
        # pad the KV stream to whole preload blocks; the in-kernel jk < S
        # mask discards the tail (DMA may not read out of bounds)
        pad = ((0, 0), (0, 0), (0, ns * bs - S), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kern = functools.partial(
        _kernel, cfg=cfg, bt=bt, bs=bs, ns=ns, S=S, T=T, group=H // K,
        scale=scale, softcap=softcap, window=window, causal=causal)
    return pl.pallas_call(
        kern,
        grid=(B, H, T // bt),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        in_specs=[
            pl.BlockSpec((1, 1, bt, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, bt, hd), lambda b, h, t: (b, h, t, 0)),
        scratch_shapes=[
            *ring_scratch(cfg, (1, 1, bs, hd), k.dtype),
            *ring_scratch(cfg, (1, 1, bs, hd), v.dtype),
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sum_ref(data: jax.Array, trace: jax.Array) -> jax.Array:
    """Trace-driven aggregation (paper's SUM microbench): sum of the rows of
    `data` selected by `trace` (with repetition)."""
    return jnp.sum(data[trace].astype(jnp.float32))


def gather_ref(data: jax.Array, trace: jax.Array) -> jax.Array:
    """Random row gather: out[i] = data[trace[i]]."""
    return data[trace]


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def attention_ref(q, k, v, *, causal: bool = True, scale: Optional[float] = None,
                  softcap: Optional[float] = None,
                  window: Optional[int] = None) -> jax.Array:
    """q: (B,H,T,hd); k,v: (B,K,S,hd) with H % K == 0 (GQA)."""
    B, H, T, hd = q.shape
    K = k.shape[1]
    G = H // K
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    S = k.shape[2]
    if causal:
        i = jnp.arange(T)[:, None] + (S - T)   # queries end-aligned with keys
        j = jnp.arange(S)[None, :]
        m = j <= i
        if window is not None:
            m &= j > i - window
        logits = jnp.where(m[None, None], logits, -2.0e38)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, vv.astype(jnp.float32)).astype(q.dtype)


def filter_ref(data: jax.Array, threshold) -> jax.Array:
    """Selection bit-vector (paper Exp. 5): bit i set iff data[i,0] > thr.
    Packed little-endian into int32 words."""
    bits = (data[:, 0] > threshold).astype(jnp.uint32)
    n = bits.shape[0]
    pad = (-n) % 32
    bits = jnp.pad(bits, (0, pad))
    words = bits.reshape(-1, 32) << jnp.arange(32, dtype=jnp.uint32)[None, :]
    return jnp.bitwise_or.reduce(words, axis=1).astype(jnp.uint32)


def filter_materialize_ref(data: jax.Array, threshold) -> jax.Array:
    """Full materialization baseline: selected rows kept, others zeroed
    (fixed-shape variant of result-set materialization)."""
    keep = data[:, 0] > threshold
    return jnp.where(keep[:, None], data, 0)


def decode_attention_ref(q, k, v, length, *, scale=None, softcap=None):
    """q: (B,H,hd); k,v: (B,K,S,hd); length: (B,) valid entries."""
    import numpy as _np
    B, H, hd = q.shape
    K, S = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = jnp.arange(S)[None, None, :] < jnp.asarray(length)[:, None, None]
    logits = jnp.where(mask, logits, -2.0e38)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vv.astype(jnp.float32)).astype(q.dtype)

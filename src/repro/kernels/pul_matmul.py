"""Tiled matmul with PUL-pipelined operand streaming.

C[i,j] = sum_k A[i,k] B[k,j]. The grid parallelizes output tiles (the "PE
array"); inside each grid step the K-dimension reduction streams A and B
tiles through distance-d preload rings while the MXU consumes the previous
tiles, and finished C tiles leave through an unload ring — compute/IO
interleaving at MXU granularity (the paper's Fig. 1 roofline argument: low
arithmetic-intensity tiles are latency-bound without PUL).

Block shapes are PULConfig knobs; defaults are MXU-aligned (128 multiples).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import PULConfig, PreloadStream, UnloadStream, pul_loop, ring_scratch


def _kernel(a_hbm, b_hbm, c_hbm, abuf, asems, bbuf, bsems, cacc, ubuf, usems,
            *, cfg: PULConfig, bm: int, bk: int, bn: int, nk: int, ni: int,
            nj: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    a_st = PreloadStream(a_hbm, abuf, asems,
                         index_map=lambda t: (i * bm, t * bk),
                         cfg=cfg, n_blocks=nk)
    b_st = PreloadStream(b_hbm, bbuf, bsems,
                         index_map=lambda t: (t * bk, j * bn),
                         cfg=cfg, n_blocks=nk)
    tile = i * nj + j
    ucfg = PULConfig(distance=1, slots=2, unload_distance=cfg.unload_distance)
    unl = UnloadStream(c_hbm, ubuf, usems,
                       index_map=lambda t: ((t // nj) * bm, (t % nj) * bn),
                       cfg=ucfg, n_blocks=ni * nj)  # double-buffered C ring

    cacc[...] = jnp.zeros(cacc.shape, cacc.dtype)

    def body(t, views, carry):
        at = views[0][...]
        bt = views[1][...]
        cacc[...] += jnp.dot(at, bt, preferred_element_type=jnp.float32)
        return carry

    pul_loop(nk, [a_st, b_st], body, 0, cfg)

    slot = unl.slot(tile)
    slot[...] = cacc[...].astype(ubuf.dtype)
    unl.issue(tile)
    # intermediate grid steps overlap the C flush with the next tile's
    # compute (slot() enforces ring reuse); the last step drains the ring
    @pl.when((i == ni - 1) & (j == nj - 1))
    def _():
        unl.drain()


def pul_matmul(a: jax.Array, b: jax.Array, *, cfg: PULConfig = PULConfig(),
               bm: int = 128, bk: int = 128, bn: int = 128,
               out_dtype=jnp.float32, interpret: bool = True) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    nk, ni, nj = K // bk, M // bm, N // bn
    kern = functools.partial(_kernel, cfg=cfg, bm=bm, bk=bk, bn=bn, nk=nk,
                             ni=ni, nj=nj)
    ucfg = PULConfig(distance=1, slots=2, unload_distance=cfg.unload_distance)
    return pl.pallas_call(
        kern,
        grid=(ni, nj),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            *ring_scratch(cfg, (bm, bk), a.dtype),
            *ring_scratch(cfg, (bk, bn), b.dtype),
            pltpu.VMEM((bm, bn), jnp.float32),
            *ring_scratch(ucfg, (bm, bn), out_dtype),
        ],
        interpret=interpret,
    )(a, b)

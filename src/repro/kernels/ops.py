"""Jit'd public wrappers for the PUL kernels.

`interpret` auto-detects the backend: interpret=True on CPU (validation
mode — the kernel body runs through the Pallas interpreter), False on real
TPU (lowers to Mosaic with actual DMA engines).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import PULConfig
from repro.kernels.pul_sum import pul_sum
from repro.kernels.pul_gather import pul_gather
from repro.kernels.pul_matmul import pul_matmul
from repro.kernels.pul_attention import pul_attention
from repro.kernels.pul_filter import pul_filter


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


@functools.partial(jax.jit, static_argnames=("cfg", "rows_per_req", "interpret"))
def sum_op(data, trace, *, cfg: PULConfig = PULConfig(),
           rows_per_req: int = 1, interpret: Optional[bool] = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return pul_sum(data, trace, cfg=cfg, rows_per_req=rows_per_req,
                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("cfg", "rows_per_req", "interpret"))
def gather_op(table, trace, *, cfg: PULConfig = PULConfig(),
              rows_per_req: int = 1, interpret: Optional[bool] = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return pul_gather(table, trace, cfg=cfg, rows_per_req=rows_per_req,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "bm", "bk", "bn", "out_dtype", "interpret"))
def matmul_op(a, b, *, cfg: PULConfig = PULConfig(), bm: int = 128,
              bk: int = 128, bn: int = 128, out_dtype=jnp.float32,
              interpret: Optional[bool] = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return pul_matmul(a, b, cfg=cfg, bm=bm, bk=bk, bn=bn,
                      out_dtype=out_dtype, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "bt", "bs", "causal", "scale", "softcap", "window", "interpret"))
def attention_op(q, k, v, *, cfg: PULConfig = PULConfig(), bt: int = 128,
                 bs: int = 128, causal: bool = True,
                 scale: Optional[float] = None,
                 softcap: Optional[float] = None,
                 window: Optional[int] = None,
                 interpret: Optional[bool] = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return pul_attention(q, k, v, cfg=cfg, bt=bt, bs=bs, causal=causal,
                         scale=scale, softcap=softcap, window=window,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "rows_per_block", "materialize", "interpret"))
def filter_op(data, threshold: float, *, cfg: PULConfig = PULConfig(),
              rows_per_block: int = 128, materialize: bool = False,
              interpret: Optional[bool] = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return pul_filter(data, threshold, cfg=cfg, rows_per_block=rows_per_block,
                      materialize=materialize, interpret=interpret)

"""Filter with bit-vector unloading (paper Experiment 5, Fig. 7).

Streams table rows through the preload ring, evaluates the predicate, and
materializes the result either as

  * a positional BIT-VECTOR (one bit per row, packed into int32 words) —
    the paper's bandwidth-saving encoding: extra interleavable compute,
    64x less unload traffic for 64B rows; or
  * the FULL rows (zero-masked), the baseline materialization whose unload
    traffic competes with the already bandwidth-bound scan.

Predicate: row[0] > threshold.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import PULConfig, PreloadStream, UnloadStream, pul_loop, ring_scratch


def _kernel_bitvec(thr_smem, data_hbm, out_hbm, pbuf, psems, ubuf, usems, *,
                   cfg: PULConfig, n_blocks: int, rows: int):
    # rows per block must be a multiple of 32 (bit-packing word width)
    words = rows // 32
    pre = PreloadStream(data_hbm, pbuf, psems,
                        index_map=lambda i: (i * rows, 0),
                        cfg=cfg, n_blocks=n_blocks)
    unl = UnloadStream(out_hbm, ubuf, usems,
                       index_map=lambda i: (i * words, 0),
                       cfg=cfg, n_blocks=n_blocks)
    thr = thr_smem[0]

    def body(i, views, carry):
        blk = views[0][...]                            # (rows, W)
        bits = (blk[:, 0] > thr).astype(jnp.uint32)    # (rows,)
        shifted = bits.reshape(words, 32) << jax.lax.broadcasted_iota(
            jnp.uint32, (words, 32), 1)
        packed = jnp.sum(shifted, axis=1, dtype=jnp.uint32)  # or of disjoint bits
        slot = unl.slot(i)
        slot[...] = packed.reshape(words, 1)
        unl.issue(i)
        return carry

    pul_loop(n_blocks, [pre], body, 0, cfg, unloads=[unl])


def _kernel_materialize(thr_smem, data_hbm, out_hbm, pbuf, psems, ubuf, usems,
                        *, cfg: PULConfig, n_blocks: int, rows: int):
    pre = PreloadStream(data_hbm, pbuf, psems,
                        index_map=lambda i: (i * rows, 0),
                        cfg=cfg, n_blocks=n_blocks)
    unl = UnloadStream(out_hbm, ubuf, usems,
                       index_map=lambda i: (i * rows, 0),
                       cfg=cfg, n_blocks=n_blocks)
    thr = thr_smem[0]

    def body(i, views, carry):
        blk = views[0][...]
        keep = blk[:, 0] > thr
        slot = unl.slot(i)
        slot[...] = jnp.where(keep[:, None], blk, 0)
        unl.issue(i)
        return carry

    pul_loop(n_blocks, [pre], body, 0, cfg, unloads=[unl])


def pul_filter(data: jax.Array, threshold: float, *,
               cfg: PULConfig = PULConfig(), rows_per_block: int = 128,
               materialize: bool = False, interpret: bool = True) -> jax.Array:
    N, W = data.shape
    rows = rows_per_block
    assert N % rows == 0 and rows % 32 == 0
    n_blocks = N // rows
    thr = jnp.asarray([threshold], data.dtype)
    if materialize:
        kern = functools.partial(_kernel_materialize, cfg=cfg,
                                 n_blocks=n_blocks, rows=rows)
        out_shape = jax.ShapeDtypeStruct((N, W), data.dtype)
        ublock = (rows, W)
        udtype = data.dtype
    else:
        kern = functools.partial(_kernel_bitvec, cfg=cfg,
                                 n_blocks=n_blocks, rows=rows)
        out_shape = jax.ShapeDtypeStruct((N // 32, 1), jnp.uint32)
        ublock = (rows // 32, 1)
        udtype = jnp.uint32
    out = pl.pallas_call(
        kern,
        out_shape=out_shape,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[*ring_scratch(cfg, (rows, W), data.dtype),
                        *ring_scratch(cfg, ublock, udtype)],
        interpret=interpret,
    )(thr, data)
    return out[:, 0] if not materialize else out
